//! Minimal JSON value model, writer and recursive-descent parser.
//!
//! `serde`/`serde_json` are not in the vendored crate set (offline build —
//! see DESIGN.md §3), so experiment records, the artifact manifest and run
//! configurations go through this module. It supports the full JSON grammar
//! except for exotic number forms beyond f64.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so emitted documents are
/// deterministically ordered (stable diffs of experiment records).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (finite f64; non-finite serializes as null).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object (BTreeMap: deterministic key order).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Empty JSON object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics on non-objects (programming error).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Member lookup on objects (`None` otherwise).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `get_path(&["entries", "stream_step", "file"])`.
    pub fn get_path(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for key in path {
            cur = cur.get(key)?;
        }
        Some(cur)
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integral value, if exactly representable.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array items, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        // JSON has no NaN/Inf; emit null like most writers.
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        fmt::Write::write_fmt(out, format_args!("{}", x as i64)).unwrap();
    } else {
        fmt::Write::write_fmt(out, format_args!("{x}")).unwrap();
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32)).unwrap()
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// Byte offset of the parse failure.
    pub pos: usize,
    /// Human-readable parse failure reason.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let Some(b) = self.peek() else {
                return Err(self.err("unterminated string"));
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        return Err(self.err("unterminated escape"));
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("bad unicode escape"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Re-scan as UTF-8: back up and take the full char.
                    self.pos -= 1;
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let Some(b) = self.peek() else {
                return Err(self.err("truncated \\u escape"));
            };
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}
impl From<&[f64]> for Json {
    fn from(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1.5", "3e8", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2, "{text}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": true}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn builder_and_path() {
        let mut j = Json::obj();
        j.set("cluster", "gros").set("epsilon", 0.15);
        let mut inner = Json::obj();
        inner.set("K_L", 25.6);
        j.set("params", inner);
        assert_eq!(j.get_path(&["params", "K_L"]).unwrap().as_f64(), Some(25.6));
        assert_eq!(j.get("cluster").unwrap().as_str(), Some("gros"));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"n": 1048576, "entries": {"stream_step": {"file": "s.hlo.txt",
            "inputs": [["f32", [1048576]]]}}, "bytes_per_step": 41943040}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(1048576));
        assert_eq!(
            v.get_path(&["entries", "stream_step", "file"])
                .unwrap()
                .as_str(),
            Some("s.hlo.txt")
        );
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""aA\n\t\\\"""#).unwrap();
        assert_eq!(v.as_str(), Some("aA\n\t\\\""));
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        for text in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "{\"a\":}", "nul"] {
            assert!(Json::parse(text).is_err(), "{text:?}");
        }
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ∀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ∀"));
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn non_finite_serializes_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }
}
