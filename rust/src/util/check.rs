//! Property-based testing mini-framework (proptest is not in the vendored
//! crate set).
//!
//! Provides seeded random case generation with shrinking-lite: on failure
//! the runner retries with "smaller" inputs produced by the generator's
//! `shrink` hook and reports the smallest failing case found. Used by the
//! coordinator/control/sim test suites for invariants (DESIGN.md §6).

use crate::util::rng::Pcg64;

/// Number of random cases per property (overridable per call).
pub const DEFAULT_CASES: usize = 256;

/// Outcome of a property over one case.
pub enum Verdict {
    /// The property held for this case.
    Pass,
    /// Failure with a human-readable reason.
    Fail(String),
    /// Case rejected by a precondition; not counted.
    Discard,
}

impl From<bool> for Verdict {
    fn from(ok: bool) -> Verdict {
        if ok {
            Verdict::Pass
        } else {
            Verdict::Fail("property returned false".to_string())
        }
    }
}

impl From<Result<(), String>> for Verdict {
    fn from(r: Result<(), String>) -> Verdict {
        match r {
            Ok(()) => Verdict::Pass,
            Err(e) => Verdict::Fail(e),
        }
    }
}

/// Run `prop` over `cases` random inputs drawn by `gen`. Panics (with the
/// seed and case number for reproduction) on the first failure after
/// attempting shrinks.
pub fn check<T, G, P, V>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> V,
    V: Into<Verdict>,
{
    let mut rng = Pcg64::new(seed, 0xC0FFEE);
    let mut executed = 0usize;
    let mut attempts = 0usize;
    while executed < cases {
        attempts += 1;
        assert!(
            attempts < cases * 20 + 100,
            "property discarded too many cases ({attempts} attempts for {cases} cases)"
        );
        let input = gen(&mut rng);
        match prop(&input).into() {
            Verdict::Pass => executed += 1,
            Verdict::Discard => continue,
            Verdict::Fail(reason) => {
                // Shrink-lite: try up to 64 fresh cases, keep failing ones
                // whose debug representation is shorter (a crude but
                // effective size proxy for numeric tuples).
                let mut best = (input.clone(), reason.clone());
                for _ in 0..64 {
                    let candidate = gen(&mut rng);
                    if format!("{candidate:?}").len() < format!("{:?}", best.0).len() {
                        if let Verdict::Fail(r) = prop(&candidate).into() {
                            best = (candidate, r);
                        }
                    }
                }
                panic!(
                    "property failed (seed={seed}, case {executed}): {}\n  input: {:?}",
                    best.1, best.0
                );
            }
        }
    }
}

/// Convenience: `check` with [`DEFAULT_CASES`].
pub fn check_default<T, G, P, V>(seed: u64, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> V,
    V: Into<Verdict>,
{
    check(seed, DEFAULT_CASES, gen, prop)
}

/// Assert two floats are close (absolute + relative tolerance), returning a
/// Verdict-friendly Result.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(1, 50, |r| r.uniform(0.0, 1.0), |x| {
            n += 1;
            *x >= 0.0 && *x < 1.0
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(2, 50, |r| r.uniform(0.0, 1.0), |x| *x < 0.5);
    }

    #[test]
    fn discards_not_counted() {
        let mut passes = 0;
        check(3, 20, |r| r.uniform(-1.0, 1.0), |x| {
            if *x < 0.0 {
                Verdict::Discard
            } else {
                passes += 1;
                Verdict::Pass
            }
        });
        assert_eq!(passes, 20);
    }

    #[test]
    #[should_panic(expected = "discarded too many")]
    fn all_discards_detected() {
        check(4, 20, |r| r.f64(), |_| Verdict::Discard);
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(close(1e6, 1e6 + 1.0, 1e-5).is_ok());
        assert!(close(1.0, 2.0, 1e-3).is_err());
    }
}
