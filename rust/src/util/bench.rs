//! Criterion-lite: a micro-benchmark harness (criterion is not in the
//! vendored crate set).
//!
//! Provides warmup + timed iterations with mean/p50/p99 statistics and
//! ops/s reporting, a `black_box` to defeat dead-code elimination, and a
//! tiny runner so `cargo bench` targets (with `harness = false`) share a
//! uniform output format:
//!
//! ```text
//! bench_name                 mean 1.234 µs   p50 1.2 µs   p99 2.0 µs   812k ops/s
//! ```

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Optimization barrier (the `std::hint::black_box` shape).
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// True when the CI smoke mode is active (`POWERCTL_BENCH_SMOKE=1`): bench
/// binaries shrink iteration counts and fleet sizes so the whole suite
/// finishes in seconds while still exercising every code path.
pub fn smoke() -> bool {
    std::env::var_os("POWERCTL_BENCH_SMOKE").is_some()
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Measured iterations.
    pub iterations: u64,
    /// Mean time per iteration.
    pub mean: Duration,
    /// Median time per iteration.
    pub p50: Duration,
    /// 99th-percentile time per iteration.
    pub p99: Duration,
}

impl BenchResult {
    /// Iterations per second implied by the mean.
    pub fn ops_per_sec(&self) -> f64 {
        if self.mean.as_secs_f64() == 0.0 {
            f64::INFINITY
        } else {
            1.0 / self.mean.as_secs_f64()
        }
    }
}

fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

fn fmt_rate(r: f64) -> String {
    if r >= 1e6 {
        format!("{:.2}M ops/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.1}k ops/s", r / 1e3)
    } else {
        format!("{r:.1} ops/s")
    }
}

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    /// Warmup wall-time budget.
    pub warmup: Duration,
    /// Measurement wall-time budget.
    pub measure: Duration,
    /// Hard cap on measured iterations (for slow end-to-end benches).
    pub max_iterations: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            max_iterations: 1_000_000,
        }
    }
}

impl Bench {
    /// For slow (seconds-long) end-to-end benches: no warmup, few iters.
    pub fn endtoend() -> Self {
        if smoke() {
            return Bench {
                warmup: Duration::ZERO,
                measure: Duration::from_millis(200),
                max_iterations: 2,
            };
        }
        Bench {
            warmup: Duration::ZERO,
            measure: Duration::from_secs(2),
            max_iterations: 5,
        }
    }

    /// The default config, capped down hard under CI smoke mode.
    pub fn scaled() -> Self {
        if smoke() {
            return Bench {
                warmup: Duration::from_millis(20),
                measure: Duration::from_millis(100),
                max_iterations: 500,
            };
        }
        Bench::default()
    }

    /// Run `f` repeatedly, print one report line, return the stats.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples = Vec::new();
        let m0 = Instant::now();
        while m0.elapsed() < self.measure && (samples.len() as u64) < self.max_iterations {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        samples.sort();
        let total: Duration = samples.iter().sum();
        let mean = total / samples.len() as u32;
        let p50 = samples[samples.len() / 2];
        let p99 = samples[(samples.len() * 99 / 100).min(samples.len() - 1)];
        let result = BenchResult {
            name: name.to_string(),
            iterations: samples.len() as u64,
            mean,
            p50,
            p99,
        };
        println!(
            "{:<44} mean {:>10}   p50 {:>10}   p99 {:>10}   {}",
            result.name,
            fmt_duration(result.mean),
            fmt_duration(result.p50),
            fmt_duration(result.p99),
            fmt_rate(result.ops_per_sec()),
        );
        result
    }
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Accumulates bench results into the machine-readable CI artifact
/// (`BENCH_l3.json`): one entry per bench (`name`, `mean_ns`,
/// `ops_per_sec`) plus free-form derived metrics (`name`, `value`) such as
/// node-ticks/s or steady-state allocation counts.
#[derive(Debug, Clone, Default)]
pub struct Report {
    entries: Vec<Json>,
}

impl Report {
    /// Empty report.
    pub fn new() -> Self {
        Report::default()
    }

    /// Record one bench result.
    pub fn add(&mut self, r: &BenchResult) {
        let mut j = Json::obj();
        j.set("name", r.name.as_str())
            .set("mean_ns", r.mean.as_nanos() as f64)
            .set("ops_per_sec", r.ops_per_sec());
        self.entries.push(j);
    }

    /// Record a derived scalar metric.
    pub fn add_metric(&mut self, name: &str, value: f64) {
        let mut j = Json::obj();
        j.set("name", name).set("value", value);
        self.entries.push(j);
    }

    /// Number of entries recorded.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The report as a JSON object (the BENCH_l3.json shape).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.entries.clone())
    }

    /// Write the report as pretty JSON.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_simple_closure() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            max_iterations: 10_000,
        };
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iterations > 100);
        assert!(r.p50 <= r.p99);
        assert!(r.ops_per_sec() > 1000.0);
    }

    #[test]
    fn endtoend_config_bounded() {
        let b = Bench::endtoend();
        let r = b.run("sleepy", || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.iterations <= 5);
    }

    #[test]
    fn report_is_valid_parseable_json() {
        let b = Bench {
            warmup: Duration::ZERO,
            measure: Duration::from_millis(10),
            max_iterations: 100,
        };
        let mut report = Report::new();
        let r = b.run("tiny", || {
            black_box(1 + 1);
        });
        report.add(&r);
        report.add_metric("node_ticks_per_s", 1.25e6);
        assert_eq!(report.len(), 2);
        let parsed = Json::parse(&report.to_json().pretty()).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("tiny"));
        assert!(arr[0].get("mean_ns").unwrap().as_f64().unwrap() >= 0.0);
        // ops_per_sec may serialize as null for a 0 ns mean (infinite
        // rate); it must still be present.
        assert!(arr[0].get("ops_per_sec").is_some());
        assert_eq!(arr[1].get("value").unwrap().as_f64(), Some(1.25e6));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_duration(Duration::from_micros(1500)).contains("ms"));
        assert!(fmt_rate(2e6).contains("M ops/s"));
    }
}
