//! Deterministic fork/join parallelism for campaign sweeps.
//!
//! Evaluation campaigns (fig7, the fleet sweeps) are embarrassingly
//! parallel: every run is seeded independently and writes nothing shared.
//! `rayon` is not in the vendored crate set, so [`par_map`] provides the one
//! primitive the sweeps need: map a function over owned items on all cores,
//! returning results **in input order** (determinism rule: parallelism must
//! never change bytes, only wall time).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (the machine's parallelism).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Map `f` over `items` on up to [`default_threads`] threads, preserving
/// input order in the output. Falls back to a sequential loop for a single
/// item or a single core. Panics in `f` propagate to the caller.
pub fn par_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let threads = default_threads().min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }

    let n = items.len();
    // Work queue: each slot is taken exactly once, tagged with its index so
    // results land back in input order regardless of scheduling.
    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|x| Mutex::new(Some(x))).collect();
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            handles.push(scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().take().expect("slot taken twice");
                let r = f(item);
                *results[i].lock().unwrap() = Some(r);
            }));
        }
        for h in handles {
            h.join().expect("par_map worker panicked");
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("par_map slot not filled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<u64> = (0..257).collect();
        let ys = par_map(xs.clone(), |x| x * 3 + 1);
        assert_eq!(ys, xs.iter().map(|x| x * 3 + 1).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(par_map(Vec::<u32>::new(), |x| x), Vec::<u32>::new());
        assert_eq!(par_map(vec![9], |x| x + 1), vec![10]);
    }

    #[test]
    fn matches_sequential_on_nontrivial_work() {
        // Same bytes as the sequential map — the determinism contract.
        let seeds: Vec<u64> = (0..32).collect();
        let seq: Vec<u64> = seeds
            .iter()
            .map(|&s| {
                let mut r = crate::util::rng::Pcg64::seeded(s);
                (0..100).map(|_| r.next_u64()).fold(0u64, u64::wrapping_add)
            })
            .collect();
        let par = par_map(seeds, |s| {
            let mut r = crate::util::rng::Pcg64::seeded(s);
            (0..100).map(|_| r.next_u64()).fold(0u64, u64::wrapping_add)
        });
        assert_eq!(seq, par);
    }

    #[test]
    fn uses_threads_without_deadlock() {
        // Just exercise the scoped-thread path with more items than cores.
        let out = par_map((0..1000u32).collect::<Vec<_>>(), |x| x % 7);
        assert_eq!(out.len(), 1000);
        assert_eq!(out[13], 6);
    }
}
