//! Deterministic fork/join parallelism: a persistent [`WorkerPool`] plus
//! the one-shot [`par_map`] built on it.
//!
//! Evaluation campaigns (fig7, the fleet sweeps) are embarrassingly
//! parallel: every run is seeded independently and writes nothing shared.
//! The fleet executor is *periodically* parallel: the same node shards are
//! ticked once per simulated second, so re-spawning OS threads every period
//! would dominate the hot path. `rayon` is not in the vendored crate set,
//! so this module provides the two primitives those callers need:
//!
//! * [`WorkerPool`] — a persistent pool with a fork/join
//!   [`broadcast`](WorkerPool::broadcast) and a
//!   [`par_chunks_mut`](WorkerPool::par_chunks_mut) that hands disjoint
//!   `&mut` chunks of one slice to the workers (no channels, no per-item
//!   locks, no allocation per call);
//! * [`par_map`] — map a function over owned items on all cores, returning
//!   results **in input order**.
//!
//! Determinism rule: parallelism must never change bytes, only wall time.
//! Both primitives uphold it structurally — workers touch disjoint state
//! claimed through an atomic index, so results cannot depend on scheduling.
//!
//! **NUMA-aware placement.** On multi-socket hosts a worker that migrates
//! sockets mid-campaign pays remote-DRAM latency on every shard array it
//! owns. [`WorkerPool::new`] therefore pins worker `w` to a core chosen
//! round-robin **across sockets** (sysfs topology, direct
//! `sched_setaffinity` syscalls — no libc in the vendored set), so
//! co-resident shards spread over memory controllers and first-touch
//! allocations (the executor adopts each shard's arrays *on its owning
//! worker*) stay local. Placement is best-effort by design: the pool
//! probes affinity support once at construction and otherwise runs
//! unpinned — never a panic — and `POWERCTL_NO_PIN=1` force-disables it.
//! [`WorkerPool::pin_status`] reports what happened; pinning can only
//! move wall time, never bytes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Once};
use std::thread::JoinHandle;

thread_local! {
    /// Set while `catch_quiet` runs on this thread: the global panic hook
    /// swallows the default stderr backtrace for panics we intend to catch
    /// and quarantine (a 1k-node campaign surviving one crashing engine
    /// must not spray a thousand-line backtrace per period).
    static SUPPRESS_PANIC_OUTPUT: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Installs the quiet-capable panic hook exactly once, process-wide.
static QUIET_HOOK: Once = Once::new();

/// `std::panic::catch_unwind` with the default panic output suppressed for
/// the duration of the call (on this thread only — other threads' panics
/// still print). Used by the fleet executor to quarantine a panicking node
/// engine at the worker boundary without flooding stderr; the payload is
/// still returned so callers can log the failure their own way.
pub(crate) fn catch_quiet<R>(f: impl FnOnce() -> R) -> std::thread::Result<R> {
    QUIET_HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if !SUPPRESS_PANIC_OUTPUT.with(|s| s.get()) {
                prev(info);
            }
        }));
    });
    SUPPRESS_PANIC_OUTPUT.with(|s| s.set(true));
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
    SUPPRESS_PANIC_OUTPUT.with(|s| s.set(false));
    r
}

/// Number of worker threads to use (the machine's parallelism).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Direct `sched_{set,get}affinity` syscalls — the vendored crate set has
/// no libc, so the Linux entry points are invoked with inline asm. Only
/// compiled on (Linux, x86_64|aarch64); everywhere else the sibling
/// fallback module reports "unsupported" and pins nothing.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod affinity {
    /// `cpu_set_t` sized for 1024 CPUs (16 × u64), the kernel default.
    const MASK_WORDS: usize = 16;

    #[cfg(target_arch = "x86_64")]
    const SYS_SETAFFINITY: usize = 203;
    #[cfg(target_arch = "x86_64")]
    const SYS_GETAFFINITY: usize = 204;
    #[cfg(target_arch = "aarch64")]
    const SYS_SETAFFINITY: usize = 122;
    #[cfg(target_arch = "aarch64")]
    const SYS_GETAFFINITY: usize = 123;

    /// Three-argument raw syscall: returns the kernel's raw result
    /// (negative errno on failure).
    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall3(nr: usize, a1: usize, a2: usize, a3: usize) -> isize {
        let ret: isize;
        // SAFETY: `syscall` with the Linux x86_64 ABI — arguments in
        // rdi/rsi/rdx, number in rax, rcx/r11 clobbered by the kernel.
        // The callers pass either value arguments or pointers to live
        // stack buffers of the advertised length.
        unsafe {
            core::arch::asm!(
                "syscall",
                inlateout("rax") nr as isize => ret,
                in("rdi") a1,
                in("rsi") a2,
                in("rdx") a3,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack),
            );
        }
        ret
    }

    /// Three-argument raw syscall (aarch64 `svc 0` ABI).
    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall3(nr: usize, a1: usize, a2: usize, a3: usize) -> isize {
        let ret: isize;
        // SAFETY: `svc 0` with the Linux aarch64 ABI — arguments in
        // x0..x2, number in x8, result in x0. Same pointer-validity
        // contract as the x86_64 twin.
        unsafe {
            core::arch::asm!(
                "svc 0",
                in("x8") nr,
                inlateout("x0") a1 as isize => ret,
                in("x1") a2,
                in("x2") a3,
                options(nostack),
            );
        }
        ret
    }

    /// Whether affinity syscalls work here, probed **read-only** with
    /// `sched_getaffinity` on the calling thread (pid 0). Sandboxes and
    /// seccomp profiles that filter the syscalls fail this probe, and the
    /// pool then never attempts a set.
    pub(super) fn supported() -> bool {
        let mut mask = [0u64; MASK_WORDS];
        let r = unsafe {
            syscall3(
                SYS_GETAFFINITY,
                0,
                std::mem::size_of_val(&mask),
                mask.as_mut_ptr() as usize,
            )
        };
        r > 0
    }

    /// Pin the calling thread to `core`; `false` on any failure (the
    /// caller degrades to unpinned, never panics).
    pub(super) fn pin_current_thread(core: usize) -> bool {
        if core >= MASK_WORDS * 64 {
            return false;
        }
        let mut mask = [0u64; MASK_WORDS];
        mask[core / 64] = 1u64 << (core % 64);
        let r = unsafe {
            syscall3(
                SYS_SETAFFINITY,
                0,
                std::mem::size_of_val(&mask),
                mask.as_ptr() as usize,
            )
        };
        r == 0
    }
}

/// Portability fallback: affinity control is a Linux-only optimization;
/// everywhere else workers run wherever the scheduler puts them.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod affinity {
    pub(super) fn supported() -> bool {
        false
    }

    pub(super) fn pin_current_thread(_core: usize) -> bool {
        false
    }
}

/// How a [`WorkerPool`] placed its workers on CPUs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PinStatus {
    /// Workers are pinned to cores chosen round-robin across sockets.
    Pinned {
        /// CPU sockets (NUMA domains) the pin cycle interleaves.
        sockets: usize,
        /// Distinct cores in the pin cycle.
        cores: usize,
    },
    /// Pinning force-disabled via `POWERCTL_NO_PIN=1`.
    Disabled,
    /// Affinity syscalls unavailable (non-Linux target, or a sandbox that
    /// filters them) — workers run unpinned.
    Unsupported,
}

/// The placement decision a pool makes once at construction: a status for
/// reporting plus the socket-interleaved core cycle worker `w` pins into
/// (`cores[w % len]`).
struct PinPlan {
    status: PinStatus,
    cores: Vec<usize>,
}

impl PinPlan {
    /// Probe the environment and build the plan (escape hatch, syscall
    /// probe, sysfs topology) — called once per pool.
    fn detect() -> Self {
        let disabled = std::env::var_os("POWERCTL_NO_PIN").is_some_and(|v| v == "1");
        PinPlan::detect_inner(disabled, affinity::supported())
    }

    /// [`detect`](Self::detect) with the environment probes injected —
    /// testable without mutating process env or depending on host
    /// affinity support.
    fn detect_inner(disabled: bool, supported: bool) -> Self {
        if disabled {
            return PinPlan {
                status: PinStatus::Disabled,
                cores: Vec::new(),
            };
        }
        if !supported {
            return PinPlan {
                status: PinStatus::Unsupported,
                cores: Vec::new(),
            };
        }
        let sockets = socket_topology();
        let cores = interleave_sockets(&sockets);
        if cores.is_empty() {
            return PinPlan {
                status: PinStatus::Unsupported,
                cores: Vec::new(),
            };
        }
        PinPlan {
            status: PinStatus::Pinned {
                sockets: sockets.len(),
                cores: cores.len(),
            },
            cores,
        }
    }

    /// Core for worker `w`, cycling through the interleaved plan.
    fn core_for(&self, w: usize) -> Option<usize> {
        if self.cores.is_empty() {
            None
        } else {
            Some(self.cores[w % self.cores.len()])
        }
    }
}

/// Cores grouped by socket (sysfs `physical_package_id`), sockets in
/// first-seen order. CPUs whose topology file is unreadable fall into an
/// implicit package 0, so hosts without the sysfs tree degrade to one
/// socket — round-robin then just spreads workers over cores.
fn socket_topology() -> Vec<Vec<usize>> {
    let mut sockets: Vec<(i64, Vec<usize>)> = Vec::new();
    for cpu in 0..default_threads() {
        let path = format!("/sys/devices/system/cpu/cpu{cpu}/topology/physical_package_id");
        let pkg = std::fs::read_to_string(&path)
            .ok()
            .and_then(|s| s.trim().parse::<i64>().ok())
            .unwrap_or(0);
        match sockets.iter_mut().find(|(id, _)| *id == pkg) {
            Some((_, cores)) => cores.push(cpu),
            None => sockets.push((pkg, vec![cpu])),
        }
    }
    sockets.into_iter().map(|(_, cores)| cores).collect()
}

/// Round-robin interleave of per-socket core lists: `[[0, 1], [2, 3]]` →
/// `[0, 2, 1, 3]`, so consecutive workers land on alternating sockets and
/// the shard arrays they first-touch spread across memory controllers.
/// Uneven sockets keep contributing until exhausted.
fn interleave_sockets(sockets: &[Vec<usize>]) -> Vec<usize> {
    let longest = sockets.iter().map(|s| s.len()).max().unwrap_or(0);
    let mut out = Vec::with_capacity(sockets.iter().map(|s| s.len()).sum());
    for i in 0..longest {
        for s in sockets {
            if let Some(&c) = s.get(i) {
                out.push(c);
            }
        }
    }
    out
}

/// Type-erased `&&(dyn Fn(usize) + Sync)`: the thin `data` pointer points
/// at the fat reference living on the broadcasting caller's stack.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
}

// SAFETY: the pointee is a `&(dyn Fn + Sync)` whose referent is Sync, and
// `broadcast` keeps it alive until every worker has finished the call.
unsafe impl Send for Job {}

unsafe fn call_erased(data: *const (), index: usize) {
    // SAFETY: `data` was produced in `broadcast` from
    // `&f as *const &(dyn Fn(usize) + Sync)`; the reference it points at
    // outlives the call (see `broadcast`).
    let f = unsafe { *(data as *const &(dyn Fn(usize) + Sync)) };
    f(index);
}

/// Current job slot, guarded by `PoolState::job`.
struct JobCell {
    /// Bumped once per broadcast; workers run the job when it advances.
    generation: u64,
    job: Option<Job>,
    shutdown: bool,
}

/// Join-side state, guarded by `PoolState::sync`.
struct SyncState {
    /// Workers still running the current generation.
    active: usize,
    /// First worker panic of the current generation (re-raised at join).
    panic: Option<Box<dyn std::any::Any + Send>>,
}

struct PoolState {
    job: Mutex<JobCell>,
    start: Condvar,
    sync: Mutex<SyncState>,
    done: Condvar,
}

/// A persistent fork/join worker pool. One broadcast wakes every worker
/// exactly once and returns when all of them have finished — the only
/// synchronization is two mutex/condvar pairs, so a steady-state fork/join
/// allocates nothing.
pub struct WorkerPool {
    state: Arc<PoolState>,
    workers: Vec<JoinHandle<()>>,
    pin_status: PinStatus,
}

fn worker_loop(state: &PoolState, index: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut cell = state.job.lock().unwrap();
            loop {
                if cell.shutdown {
                    return;
                }
                if cell.generation != seen {
                    seen = cell.generation;
                    break cell.job.expect("pool generation advanced without a job");
                }
                cell = state.start.wait(cell).unwrap();
            }
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // SAFETY: `broadcast` does not return until every worker has
            // finished this generation, so the closure behind `job.data`
            // is still alive here.
            unsafe { (job.call)(job.data, index) }
        }));
        let mut sync = state.sync.lock().unwrap();
        if let Err(payload) = result {
            if sync.panic.is_none() {
                sync.panic = Some(payload);
            }
        }
        sync.active -= 1;
        if sync.active == 0 {
            state.done.notify_all();
        }
    }
}

impl WorkerPool {
    /// Spawn a pool of `threads` persistent workers (at least one), each
    /// pinned to a core chosen round-robin across sockets when the host
    /// supports it (see the module docs; [`pin_status`](Self::pin_status)
    /// reports the outcome). A worker pins **itself** before entering its
    /// loop, so everything it later first-touches — notably the shard
    /// arrays the fleet executor adopts inside worker broadcasts — is
    /// allocated NUMA-local to where the worker stays.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let plan = Arc::new(PinPlan::detect());
        let state = Arc::new(PoolState {
            job: Mutex::new(JobCell {
                generation: 0,
                job: None,
                shutdown: false,
            }),
            start: Condvar::new(),
            sync: Mutex::new(SyncState {
                active: 0,
                panic: None,
            }),
            done: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let st = state.clone();
                let pl = plan.clone();
                std::thread::spawn(move || {
                    if let Some(core) = pl.core_for(i) {
                        // Best-effort: a failed pin (cpuset shrunk after
                        // the probe, hotplug) leaves the worker unpinned.
                        let _ = affinity::pin_current_thread(core);
                    }
                    worker_loop(&st, i)
                })
            })
            .collect();
        WorkerPool {
            state,
            workers,
            pin_status: plan.status,
        }
    }

    /// Number of persistent workers in the pool.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// How this pool's workers were placed on CPUs — decided once at
    /// construction, never a panic path (the bench report surfaces it).
    pub fn pin_status(&self) -> PinStatus {
        self.pin_status
    }

    /// Fork/join: run `f(worker_index)` once on every worker and return
    /// when all have finished. A panic in any worker is re-raised here
    /// after the join (the pool itself stays usable).
    pub fn broadcast(&mut self, f: &(dyn Fn(usize) + Sync)) {
        let n = self.workers.len();
        {
            let mut sync = self.state.sync.lock().unwrap();
            debug_assert_eq!(sync.active, 0, "overlapping broadcast");
            sync.active = n;
        }
        {
            let mut cell = self.state.job.lock().unwrap();
            cell.generation = cell.generation.wrapping_add(1);
            cell.job = Some(Job {
                data: &f as *const &(dyn Fn(usize) + Sync) as *const (),
                call: call_erased,
            });
            self.state.start.notify_all();
        }
        let panic = {
            let mut sync = self.state.sync.lock().unwrap();
            while sync.active > 0 {
                sync = self.state.done.wait(sync).unwrap();
            }
            sync.panic.take()
        };
        // Drop the (now dangling-to-be) job pointer before returning.
        self.state.job.lock().unwrap().job = None;
        if let Some(payload) = panic {
            std::panic::resume_unwind(payload);
        }
    }

    /// Process `items` in contiguous chunks of (at most) `chunk` elements:
    /// workers claim chunk indices through an atomic counter and receive
    /// disjoint `&mut` sub-slices — `f(start_index, chunk_slice)`. Every
    /// element is visited exactly once; no per-call allocation.
    pub fn par_chunks_mut<T, F>(&mut self, items: &mut [T], chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        let chunk = chunk.max(1);
        let n_chunks = n.div_ceil(chunk);
        let next = AtomicUsize::new(0);
        let base = SendPtr(items.as_mut_ptr());
        self.broadcast(&|_worker| loop {
            let ci = next.fetch_add(1, Ordering::Relaxed);
            if ci >= n_chunks {
                break;
            }
            let start = ci * chunk;
            let len = chunk.min(n - start);
            // SAFETY: chunk indices are claimed exactly once, so these
            // sub-slices are disjoint across workers, and `broadcast`
            // joins every worker before the borrow of `items` ends.
            let slice = unsafe { std::slice::from_raw_parts_mut(base.get().add(start), len) };
            f(start, slice);
        });
    }

    /// Order-preserving parallel map over owned items (the engine behind
    /// [`par_map`]). Indices are claimed through an atomic counter; each
    /// item is taken from and each result written to its own slot through
    /// disjoint `&mut` access — no per-item locks.
    pub fn map_vec<T, R, F>(&mut self, items: Vec<T>, f: &F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        let mut slots: Vec<Option<T>> = items.into_iter().map(Some).collect();
        let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let next = AtomicUsize::new(0);
        let src = SendPtr(slots.as_mut_ptr());
        let dst = SendPtr(results.as_mut_ptr());
        self.broadcast(&|_worker| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            // SAFETY: each index is claimed exactly once via `next`, so
            // slot accesses are disjoint across workers, and `broadcast`
            // joins before `slots`/`results` are touched again. Taking in
            // place (not `ptr::read`) keeps every slot valid if `f`
            // panics mid-run.
            let item = unsafe { (*src.get().add(i)).take().expect("slot claimed twice") };
            let r = f(item);
            unsafe {
                *dst.get().add(i) = Some(r);
            }
        });
        results
            .into_iter()
            .map(|r| r.expect("par_map slot not filled"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut cell = self.state.job.lock().unwrap();
            cell.shutdown = true;
            self.state.start.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Raw-pointer wrapper asserting that cross-thread access is externally
/// synchronized (disjoint index claims bounded by a fork/join). Crate-
/// visible so fork/join callers with structurally disjoint writes — the
/// fleet executor filling its node-order report buffer through per-shard
/// slices — can uphold the same contract without per-item locks.
pub(crate) struct SendPtr<T>(*mut T);

// SAFETY: every use guarantees disjoint access plus a join barrier before
// the pointee is reused (the constructor's documented contract).
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Wrap `ptr`. Callers must guarantee all cross-thread accesses
    /// through the wrapper are disjoint and bounded by a fork/join.
    pub(crate) fn new(ptr: *mut T) -> Self {
        SendPtr(ptr)
    }

    /// The wrapped raw pointer.
    pub(crate) fn get(&self) -> *mut T {
        self.0
    }
}

/// Map `f` over `items` on up to [`default_threads`] threads, preserving
/// input order in the output. Falls back to a sequential loop for a single
/// item or a single core. Panics in `f` propagate to the caller.
pub fn par_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let threads = default_threads().min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    WorkerPool::new(threads).map_vec(items, &f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<u64> = (0..257).collect();
        let ys = par_map(xs.clone(), |x| x * 3 + 1);
        assert_eq!(ys, xs.iter().map(|x| x * 3 + 1).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(par_map(Vec::<u32>::new(), |x| x), Vec::<u32>::new());
        assert_eq!(par_map(vec![9], |x| x + 1), vec![10]);
    }

    #[test]
    fn catch_quiet_returns_ok_and_err() {
        assert_eq!(catch_quiet(|| 41 + 1).unwrap(), 42);
        let err = catch_quiet(|| -> u32 { panic!("boom") });
        assert!(err.is_err());
        // The hook must be restored to pass-through: a normal closure
        // afterwards still works and the thread is unpoisoned.
        assert_eq!(catch_quiet(|| 7).unwrap(), 7);
    }

    #[test]
    fn matches_sequential_on_nontrivial_work() {
        // Same bytes as the sequential map — the determinism contract.
        let seeds: Vec<u64> = (0..32).collect();
        let seq: Vec<u64> = seeds
            .iter()
            .map(|&s| {
                let mut r = crate::util::rng::Pcg64::seeded(s);
                (0..100).map(|_| r.next_u64()).fold(0u64, u64::wrapping_add)
            })
            .collect();
        let par = par_map(seeds, |s| {
            let mut r = crate::util::rng::Pcg64::seeded(s);
            (0..100).map(|_| r.next_u64()).fold(0u64, u64::wrapping_add)
        });
        assert_eq!(seq, par);
    }

    #[test]
    fn uses_threads_without_deadlock() {
        // Just exercise the pool path with more items than cores.
        let out = par_map((0..1000u32).collect::<Vec<_>>(), |x| x % 7);
        assert_eq!(out.len(), 1000);
        assert_eq!(out[13], 6);
    }

    #[test]
    fn pool_broadcast_runs_every_worker_and_is_reusable() {
        let mut pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        for _ in 0..10 {
            let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
            pool.broadcast(&|w| {
                hits[w].fetch_add(1, Ordering::Relaxed);
            });
            for h in &hits {
                assert_eq!(h.load(Ordering::Relaxed), 1);
            }
        }
    }

    #[test]
    fn par_chunks_mut_visits_every_element_once() {
        let mut pool = WorkerPool::new(3);
        for (n, chunk) in [(103usize, 10usize), (7, 100), (64, 1), (1, 1), (0, 4)] {
            let mut xs: Vec<u64> = (0..n as u64).collect();
            pool.par_chunks_mut(&mut xs, chunk, |start, sl| {
                for (off, x) in sl.iter_mut().enumerate() {
                    assert_eq!(*x, (start + off) as u64, "wrong slice offset");
                    *x += 1000;
                }
            });
            assert!(
                xs.iter().enumerate().all(|(i, &x)| x == i as u64 + 1000),
                "n={n} chunk={chunk}: {xs:?}"
            );
        }
    }

    #[test]
    fn single_thread_pool_works() {
        let mut pool = WorkerPool::new(1);
        let mut xs = vec![1u32; 50];
        pool.par_chunks_mut(&mut xs, 8, |_, sl| {
            for x in sl {
                *x *= 2;
            }
        });
        assert!(xs.iter().all(|&x| x == 2));
        let ys = pool.map_vec(vec![1, 2, 3], &|x: i32| x * x);
        assert_eq!(ys, vec![1, 4, 9]);
    }

    #[test]
    fn pin_plan_escape_hatch_and_probe_failure() {
        let disabled = PinPlan::detect_inner(true, true);
        assert_eq!(disabled.status, PinStatus::Disabled);
        assert_eq!(disabled.core_for(0), None);
        let unsupported = PinPlan::detect_inner(false, false);
        assert_eq!(unsupported.status, PinStatus::Unsupported);
        assert_eq!(unsupported.core_for(3), None);
    }

    #[test]
    fn pin_plan_on_this_host_is_consistent() {
        // Whatever the host supports, the plan must be internally
        // coherent: a Pinned status advertises exactly the cycle length,
        // the cycle holds distinct maskable cores, and cycling wraps.
        let plan = PinPlan::detect_inner(false, affinity::supported());
        match plan.status {
            PinStatus::Pinned { sockets, cores } => {
                assert!(sockets >= 1);
                assert_eq!(cores, plan.cores.len());
                assert!(cores >= 1 && cores <= default_threads());
                assert!(plan.cores.iter().all(|&c| c < 1024));
                let mut sorted = plan.cores.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), plan.cores.len(), "duplicate cores");
                assert_eq!(plan.core_for(0), plan.core_for(plan.cores.len()));
            }
            PinStatus::Unsupported => assert!(plan.cores.is_empty()),
            PinStatus::Disabled => panic!("not disabled here"),
        }
    }

    #[test]
    fn interleave_alternates_sockets() {
        let two = vec![vec![0, 1], vec![2, 3]];
        assert_eq!(interleave_sockets(&two), vec![0, 2, 1, 3]);
        let uneven = vec![vec![0, 1, 2], vec![3]];
        assert_eq!(interleave_sockets(&uneven), vec![0, 3, 1, 2]);
        let one = vec![vec![4, 5, 6]];
        assert_eq!(interleave_sockets(&one), vec![4, 5, 6]);
        assert!(interleave_sockets(&[]).is_empty());
    }

    #[test]
    fn out_of_range_pin_fails_gracefully() {
        // 1024 CPUs is the mask width; beyond it the pin must refuse, not
        // corrupt a mask or panic.
        assert!(!affinity::pin_current_thread(100_000));
    }

    #[test]
    fn pinned_pool_still_runs_and_reports_status() {
        // Construction must succeed whatever the host's affinity support;
        // the status is readable and the pool functional either way.
        let mut pool = WorkerPool::new(3);
        match pool.pin_status() {
            PinStatus::Pinned { sockets, cores } => {
                assert!(sockets >= 1 && cores >= 1);
            }
            PinStatus::Disabled | PinStatus::Unsupported => {}
        }
        let hits = AtomicUsize::new(0);
        pool.broadcast(&|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn pool_survives_worker_panic() {
        let mut pool = WorkerPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.broadcast(&|w| {
                if w == 0 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err(), "worker panic was swallowed");
        // The pool stays usable after a panicked generation.
        let done = AtomicUsize::new(0);
        pool.broadcast(&|_| {
            done.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(done.load(Ordering::Relaxed), 2);
    }
}
