//! Versioned, checksummed, self-describing binary snapshot codec.
//!
//! The checkpoint/restore layer (DESIGN.md "Checkpoint/restore") needs to
//! persist every bit of live controller state — RNG streams, PI
//! integrators, resident kernel arrays, fault cursors — and resume
//! **byte-identically**. `serde`/`bincode` are not in the vendored crate
//! set (offline build, DESIGN.md §3), so this module hand-rolls the codec:
//!
//! ```text
//! file   := magic "PCTLSNAP" | version u32 | nsections u32
//!           | section* | file_crc u32
//! section:= name_len u32 | name bytes | payload_len u64 | payload
//!           | section_crc u32            (CRC-32 over name ‖ payload)
//! ```
//!
//! All integers are little-endian; `f64`s are stored as their exact IEEE-754
//! bit patterns (`to_bits`/`from_bits`), so NaN payloads, signed zeros and
//! subnormals round-trip bit-for-bit. Every value inside a payload carries a
//! one-byte type tag, which makes decode failures *descriptive* ("section
//! 'node.3': expected f64 at byte 17, found tag 0x03") instead of silently
//! misaligned. The trailing file-level CRC-32 covers every preceding byte,
//! so truncation at any offset and single-bit corruption anywhere are both
//! rejected with a [`crate::util::error::Error`] — never a panic, never a
//! silently-wrong restore.
//!
//! [`SnapshotWriter::write_atomic`] provides crash consistency: the bytes
//! go to a sibling `*.tmp` file which is fsynced and then renamed over the
//! destination, so a crash mid-write leaves the previous checkpoint intact.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Error, Result};

/// File magic: identifies a powerctl snapshot.
const MAGIC: &[u8; 8] = b"PCTLSNAP";

/// Codec version; bump on any layout change. Mismatched files are rejected.
pub const SNAPSHOT_VERSION: u32 = 1;

/// Hard cap on section name / payload lengths accepted by the decoder, so
/// a corrupted length field cannot trigger an absurd allocation.
const MAX_SECTION_LEN: u64 = 1 << 32;

// Per-value type tags (one byte before every encoded value).
const TAG_U8: u8 = 0x01;
const TAG_U32: u8 = 0x02;
const TAG_U64: u8 = 0x03;
const TAG_F64: u8 = 0x04;
const TAG_BOOL: u8 = 0x05;
const TAG_STR: u8 = 0x06;
const TAG_NONE: u8 = 0x07;
const TAG_SOME: u8 = 0x08;
const TAG_F64S: u8 = 0x09;

const CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// Streaming CRC-32 (IEEE 802.3 polynomial) update; start from
/// [`CRC_INIT`], finish by XOR with `0xFFFF_FFFF`.
const CRC_INIT: u32 = 0xFFFF_FFFF;

fn crc_update(mut state: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        state = CRC_TABLE[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

/// CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    crc_update(CRC_INIT, bytes) ^ 0xFFFF_FFFF
}

/// Every stateful layer implements this pair: `save` appends the layer's
/// live state to a [`Section`], `restore` consumes the same values in the
/// same order from a decoded section. Implementations live in the module
/// that owns the (usually private) fields, and `restore` must validate
/// structural expectations (counts, variant tags) with descriptive errors
/// rather than panicking.
pub trait Snapshot {
    /// Append this layer's state to the section.
    fn save(&self, w: &mut Section);
    /// Overwrite this layer's state from the section cursor.
    fn restore(&mut self, r: &mut Section) -> Result<()>;
}

/// One named, independently-checksummed chunk of a snapshot. Acts as a
/// write buffer (`put_*`) while building and as a cursor-tracked reader
/// (`take_*`) after decoding.
#[derive(Debug, Clone)]
pub struct Section {
    name: String,
    buf: Vec<u8>,
    cursor: usize,
}

impl Section {
    fn new(name: &str) -> Self {
        Section {
            name: name.to_string(),
            buf: Vec::new(),
            cursor: 0,
        }
    }

    /// The section's name (as written in the file).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Encoded payload length in bytes.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when the payload is empty.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    // ---- encoding ----

    /// Append a `u8`.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(TAG_U8);
        self.buf.push(v);
    }

    /// Append a `u32`.
    pub fn put_u32(&mut self, v: u32) {
        self.buf.push(TAG_U32);
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`.
    pub fn put_u64(&mut self, v: u64) {
        self.buf.push(TAG_U64);
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f64` as its exact bit pattern (NaN payloads, signed
    /// zeros and subnormals survive).
    pub fn put_f64(&mut self, v: f64) {
        self.buf.push(TAG_F64);
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    /// Append a `bool`.
    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(TAG_BOOL);
        self.buf.push(v as u8);
    }

    /// Append a UTF-8 string.
    pub fn put_str(&mut self, v: &str) {
        self.buf.push(TAG_STR);
        self.buf
            .extend_from_slice(&(v.len() as u32).to_le_bytes());
        self.buf.extend_from_slice(v.as_bytes());
    }

    /// Append an `Option<f64>`.
    pub fn put_opt_f64(&mut self, v: Option<f64>) {
        match v {
            None => self.buf.push(TAG_NONE),
            Some(x) => {
                self.buf.push(TAG_SOME);
                self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
            }
        }
    }

    /// Append an `f64` slice as one length-prefixed run of bit patterns.
    pub fn put_f64s(&mut self, vs: &[f64]) {
        self.buf.push(TAG_F64S);
        self.buf
            .extend_from_slice(&(vs.len() as u64).to_le_bytes());
        for &v in vs {
            self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }

    // ---- decoding ----

    fn short(&self, what: &str) -> Error {
        crate::err!(
            "snapshot section '{}': truncated while reading {} at byte {} (len {})",
            self.name,
            what,
            self.cursor,
            self.buf.len()
        )
    }

    fn raw_bytes(&mut self, n: usize, what: &str) -> Result<&[u8]> {
        if self.cursor + n > self.buf.len() {
            return Err(self.short(what));
        }
        let s = &self.buf[self.cursor..self.cursor + n];
        self.cursor += n;
        Ok(s)
    }

    fn raw_u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.raw_bytes(1, what)?[0])
    }

    fn raw_u32(&mut self, what: &str) -> Result<u32> {
        let b = self.raw_bytes(4, what)?;
        Ok(u32::from_le_bytes(b.try_into().unwrap()))
    }

    fn raw_u64(&mut self, what: &str) -> Result<u64> {
        let b = self.raw_bytes(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn expect_tag(&mut self, want: u8, what: &str) -> Result<()> {
        let at = self.cursor;
        let got = self.raw_u8(what)?;
        if got != want {
            return Err(crate::err!(
                "snapshot section '{}': expected {} at byte {}, found tag {:#04x}",
                self.name,
                what,
                at,
                got
            ));
        }
        Ok(())
    }

    /// Read the next `u8`.
    pub fn take_u8(&mut self) -> Result<u8> {
        self.expect_tag(TAG_U8, "u8")?;
        self.raw_u8("u8")
    }

    /// Read the next `u32`.
    pub fn take_u32(&mut self) -> Result<u32> {
        self.expect_tag(TAG_U32, "u32")?;
        self.raw_u32("u32")
    }

    /// Read the next `u64`.
    pub fn take_u64(&mut self) -> Result<u64> {
        self.expect_tag(TAG_U64, "u64")?;
        self.raw_u64("u64")
    }

    /// Read the next `f64` (exact bit pattern).
    pub fn take_f64(&mut self) -> Result<f64> {
        self.expect_tag(TAG_F64, "f64")?;
        Ok(f64::from_bits(self.raw_u64("f64")?))
    }

    /// Read the next `bool`.
    pub fn take_bool(&mut self) -> Result<bool> {
        self.expect_tag(TAG_BOOL, "bool")?;
        match self.raw_u8("bool")? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(crate::err!(
                "snapshot section '{}': invalid bool byte {:#04x}",
                self.name,
                b
            )),
        }
    }

    /// Read the next string.
    pub fn take_str(&mut self) -> Result<String> {
        self.expect_tag(TAG_STR, "str")?;
        let n = self.raw_u32("str length")? as usize;
        let bytes = self.raw_bytes(n, "str bytes")?.to_vec();
        String::from_utf8(bytes).map_err(|e| {
            crate::err!(
                "snapshot section '{}': invalid utf-8 in string: {e}",
                self.name
            )
        })
    }

    /// Read the next `Option<f64>`.
    pub fn take_opt_f64(&mut self) -> Result<Option<f64>> {
        let at = self.cursor;
        match self.raw_u8("option tag")? {
            TAG_NONE => Ok(None),
            TAG_SOME => Ok(Some(f64::from_bits(self.raw_u64("Some(f64)")?))),
            t => Err(crate::err!(
                "snapshot section '{}': expected option at byte {}, found tag {:#04x}",
                self.name,
                at,
                t
            )),
        }
    }

    /// Read the next `f64` run into a fresh vector.
    pub fn take_f64s(&mut self) -> Result<Vec<f64>> {
        self.expect_tag(TAG_F64S, "f64 slice")?;
        let n = self.raw_u64("f64 slice length")?;
        if n > MAX_SECTION_LEN / 8 {
            return Err(crate::err!(
                "snapshot section '{}': implausible f64 slice length {n}",
                self.name
            ));
        }
        let mut out = Vec::with_capacity(n as usize);
        for _ in 0..n {
            out.push(f64::from_bits(self.raw_u64("f64 slice element")?));
        }
        Ok(out)
    }

    /// Error unless every payload byte has been consumed — catches schema
    /// drift where a reader stops short of what the writer recorded.
    pub fn expect_end(&self) -> Result<()> {
        if self.cursor != self.buf.len() {
            return Err(crate::err!(
                "snapshot section '{}': {} unread bytes after decode (schema mismatch?)",
                self.name,
                self.buf.len() - self.cursor
            ));
        }
        Ok(())
    }
}

/// Builds a snapshot as an ordered list of named sections and serializes
/// it with per-section and file-level CRCs.
#[derive(Debug, Default)]
pub struct SnapshotWriter {
    sections: Vec<Section>,
}

impl SnapshotWriter {
    /// Empty snapshot.
    pub fn new() -> Self {
        SnapshotWriter::default()
    }

    /// Start (or continue) the section called `name` and return its buffer.
    pub fn section(&mut self, name: &str) -> &mut Section {
        if let Some(i) = self.sections.iter().position(|s| s.name == name) {
            return &mut self.sections[i];
        }
        self.sections.push(Section::new(name));
        self.sections.last_mut().unwrap()
    }

    /// Serialize to the on-disk byte layout (see module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for s in &self.sections {
            out.extend_from_slice(&(s.name.len() as u32).to_le_bytes());
            out.extend_from_slice(s.name.as_bytes());
            out.extend_from_slice(&(s.buf.len() as u64).to_le_bytes());
            out.extend_from_slice(&s.buf);
            let mut c = crc_update(CRC_INIT, s.name.as_bytes());
            c = crc_update(c, &s.buf);
            out.extend_from_slice(&(c ^ 0xFFFF_FFFF).to_le_bytes());
        }
        let file_crc = crc32(&out);
        out.extend_from_slice(&file_crc.to_le_bytes());
        out
    }

    /// Write the snapshot to `path` crash-consistently: the bytes go to a
    /// sibling `<path>.tmp`, which is fsynced and then atomically renamed
    /// over `path`. A crash at any point leaves either the previous
    /// checkpoint or the complete new one — never a torn file under `path`.
    pub fn write_atomic(&self, path: &Path) -> Result<()> {
        let bytes = self.to_bytes();
        let mut tmp_os = path.as_os_str().to_owned();
        tmp_os.push(".tmp");
        let tmp = PathBuf::from(tmp_os);
        {
            let mut f = fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&bytes)
                .with_context(|| format!("writing {}", tmp.display()))?;
            f.sync_all()
                .with_context(|| format!("fsyncing {}", tmp.display()))?;
        }
        fs::rename(&tmp, path).with_context(|| {
            format!("renaming {} over {}", tmp.display(), path.display())
        })?;
        Ok(())
    }
}

/// Decodes and validates a snapshot; hands out sections for `take_*` reads.
#[derive(Debug)]
pub struct SnapshotReader {
    sections: Vec<Section>,
}

impl SnapshotReader {
    /// Read and validate a snapshot file.
    pub fn read(path: &Path) -> Result<Self> {
        let bytes = fs::read(path)
            .with_context(|| format!("reading snapshot {}", path.display()))?;
        Self::from_bytes(&bytes)
            .with_context(|| format!("decoding snapshot {}", path.display()))
    }

    /// Decode and validate a snapshot from raw bytes. Rejects bad magic,
    /// version mismatches, truncation at any offset, and any CRC failure.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        // Fixed header (magic + version + nsections) and trailing file CRC.
        if bytes.len() < MAGIC.len() + 4 + 4 + 4 {
            return Err(crate::err!(
                "snapshot too short: {} bytes (truncated?)",
                bytes.len()
            ));
        }
        if &bytes[..MAGIC.len()] != MAGIC {
            return Err(Error::msg("not a powerctl snapshot (bad magic)"));
        }
        let body = &bytes[..bytes.len() - 4];
        let stored_crc =
            u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        let actual_crc = crc32(body);
        if stored_crc != actual_crc {
            return Err(crate::err!(
                "snapshot file CRC mismatch: stored {stored_crc:#010x}, computed {actual_crc:#010x} (torn or corrupted file)"
            ));
        }
        let mut pos = MAGIC.len();
        let version = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
        pos += 4;
        if version != SNAPSHOT_VERSION {
            return Err(crate::err!(
                "snapshot version {version} not supported (this build reads version {SNAPSHOT_VERSION})"
            ));
        }
        let nsections =
            u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 4;
        let mut sections = Vec::with_capacity(nsections.min(1024));
        let take = |pos: &mut usize, n: usize, what: &str| -> Result<&[u8]> {
            if *pos + n > body.len() {
                return Err(crate::err!(
                    "snapshot truncated while reading {what} at byte {pos}"
                ));
            }
            let s = &body[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        for i in 0..nsections {
            let name_len = u32::from_le_bytes(
                take(&mut pos, 4, "section name length")?.try_into().unwrap(),
            ) as u64;
            if name_len > MAX_SECTION_LEN {
                return Err(crate::err!(
                    "snapshot section {i}: implausible name length {name_len}"
                ));
            }
            let name_bytes = take(&mut pos, name_len as usize, "section name")?.to_vec();
            let name = String::from_utf8(name_bytes).map_err(|e| {
                crate::err!("snapshot section {i}: invalid utf-8 name: {e}")
            })?;
            let payload_len = u64::from_le_bytes(
                take(&mut pos, 8, "section payload length")?.try_into().unwrap(),
            );
            if payload_len > MAX_SECTION_LEN {
                return Err(crate::err!(
                    "snapshot section '{name}': implausible payload length {payload_len}"
                ));
            }
            let payload = take(&mut pos, payload_len as usize, "section payload")?.to_vec();
            let stored = u32::from_le_bytes(
                take(&mut pos, 4, "section CRC")?.try_into().unwrap(),
            );
            let mut c = crc_update(CRC_INIT, name.as_bytes());
            c = crc_update(c, &payload);
            let actual = c ^ 0xFFFF_FFFF;
            if stored != actual {
                return Err(crate::err!(
                    "snapshot section '{name}': CRC mismatch (stored {stored:#010x}, computed {actual:#010x})"
                ));
            }
            sections.push(Section {
                name,
                buf: payload,
                cursor: 0,
            });
        }
        if pos != body.len() {
            return Err(crate::err!(
                "snapshot has {} trailing bytes after the last section",
                body.len() - pos
            ));
        }
        Ok(SnapshotReader { sections })
    }

    /// The section called `name`, with its read cursor, or a descriptive
    /// error when the file does not contain it.
    pub fn section(&mut self, name: &str) -> Result<&mut Section> {
        self.sections
            .iter_mut()
            .find(|s| s.name == name)
            .ok_or_else(|| crate::err!("snapshot has no section '{name}'"))
    }

    /// True when the snapshot contains a section called `name`.
    pub fn has_section(&self, name: &str) -> bool {
        self.sections.iter().any(|s| s.name == name)
    }

    /// Names of all sections, in file order.
    pub fn section_names(&self) -> Vec<&str> {
        self.sections.iter().map(|s| s.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn sample_snapshot() -> SnapshotWriter {
        let mut w = SnapshotWriter::new();
        let s = w.section("alpha");
        s.put_u64(42);
        s.put_f64(std::f64::consts::PI);
        s.put_bool(true);
        s.put_str("hello");
        s.put_opt_f64(None);
        s.put_opt_f64(Some(-0.0));
        let s = w.section("beta");
        s.put_u8(7);
        s.put_u32(123456);
        s.put_f64s(&[1.0, f64::NEG_INFINITY, 5e-324]);
        w
    }

    #[test]
    fn round_trip_basic() {
        let bytes = sample_snapshot().to_bytes();
        let mut r = SnapshotReader::from_bytes(&bytes).unwrap();
        assert_eq!(r.section_names(), vec!["alpha", "beta"]);
        let s = r.section("alpha").unwrap();
        assert_eq!(s.take_u64().unwrap(), 42);
        assert_eq!(s.take_f64().unwrap(), std::f64::consts::PI);
        assert!(s.take_bool().unwrap());
        assert_eq!(s.take_str().unwrap(), "hello");
        assert_eq!(s.take_opt_f64().unwrap(), None);
        let z = s.take_opt_f64().unwrap().unwrap();
        assert_eq!(z.to_bits(), (-0.0f64).to_bits());
        s.expect_end().unwrap();
        let s = r.section("beta").unwrap();
        assert_eq!(s.take_u8().unwrap(), 7);
        assert_eq!(s.take_u32().unwrap(), 123456);
        let vs = s.take_f64s().unwrap();
        assert_eq!(vs.len(), 3);
        assert_eq!(vs[1], f64::NEG_INFINITY);
        assert_eq!(vs[2].to_bits(), 5e-324f64.to_bits());
        s.expect_end().unwrap();
    }

    #[test]
    fn f64_bit_patterns_survive_exactly() {
        // Random bit patterns, plus the adversarial corners: NaNs with
        // payloads, signalling-style NaNs, ±0.0, subnormals, infinities.
        let mut rng = Pcg64::seeded(0x5EED);
        let mut patterns: Vec<u64> = (0..512).map(|_| rng.next_u64()).collect();
        patterns.extend([
            0x7FF8_0000_0000_0001, // quiet NaN, payload 1
            0x7FF0_0000_0000_0001, // signalling-style NaN
            0xFFF8_DEAD_BEEF_CAFE, // negative NaN with payload
            0x8000_0000_0000_0000, // -0.0
            0x0000_0000_0000_0000, // +0.0
            0x0000_0000_0000_0001, // smallest subnormal
            0x000F_FFFF_FFFF_FFFF, // largest subnormal
            0x7FF0_0000_0000_0000, // +inf
            0xFFF0_0000_0000_0000, // -inf
        ]);
        let mut w = SnapshotWriter::new();
        let s = w.section("bits");
        for &p in &patterns {
            s.put_f64(f64::from_bits(p));
        }
        let bytes = w.to_bytes();
        let mut r = SnapshotReader::from_bytes(&bytes).unwrap();
        let s = r.section("bits").unwrap();
        for &p in &patterns {
            assert_eq!(s.take_f64().unwrap().to_bits(), p);
        }
        s.expect_end().unwrap();
    }

    #[test]
    fn truncation_at_every_byte_offset_rejected() {
        let bytes = sample_snapshot().to_bytes();
        for n in 0..bytes.len() {
            assert!(
                SnapshotReader::from_bytes(&bytes[..n]).is_err(),
                "truncation to {n}/{} bytes was accepted",
                bytes.len()
            );
        }
    }

    #[test]
    fn single_bit_corruption_rejected_everywhere() {
        let bytes = sample_snapshot().to_bytes();
        // Flip one bit per byte position (cycling through bit indices so
        // every byte is covered without 8x the work).
        for (i, _) in bytes.iter().enumerate() {
            let mut bad = bytes.clone();
            bad[i] ^= 1 << (i % 8);
            assert!(
                SnapshotReader::from_bytes(&bad).is_err(),
                "bit flip at byte {i} was accepted"
            );
        }
    }

    #[test]
    fn version_mismatch_rejected() {
        let mut bytes = sample_snapshot().to_bytes();
        // Bump the version field and re-stamp the file CRC so the version
        // check itself (not the CRC) is what rejects the file.
        let v = SNAPSHOT_VERSION + 1;
        bytes[8..12].copy_from_slice(&v.to_le_bytes());
        let n = bytes.len();
        let crc = crc32(&bytes[..n - 4]);
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let e = SnapshotReader::from_bytes(&bytes).unwrap_err();
        assert!(e.to_string().contains("version"), "{e}");
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample_snapshot().to_bytes();
        bytes[0] = b'X';
        assert!(SnapshotReader::from_bytes(&bytes).is_err());
    }

    #[test]
    fn wrong_type_tag_is_descriptive() {
        let mut w = SnapshotWriter::new();
        w.section("s").put_u64(5);
        let bytes = w.to_bytes();
        let mut r = SnapshotReader::from_bytes(&bytes).unwrap();
        let e = r.section("s").unwrap().take_f64().unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("expected f64"), "{msg}");
        assert!(msg.contains("'s'"), "{msg}");
    }

    #[test]
    fn missing_section_is_descriptive() {
        let bytes = sample_snapshot().to_bytes();
        let mut r = SnapshotReader::from_bytes(&bytes).unwrap();
        let e = r.section("gamma").unwrap_err();
        assert!(e.to_string().contains("gamma"), "{e}");
    }

    #[test]
    fn unread_bytes_detected() {
        let mut w = SnapshotWriter::new();
        let s = w.section("s");
        s.put_u64(1);
        s.put_u64(2);
        let bytes = w.to_bytes();
        let mut r = SnapshotReader::from_bytes(&bytes).unwrap();
        let s = r.section("s").unwrap();
        s.take_u64().unwrap();
        assert!(s.expect_end().is_err());
    }

    #[test]
    fn snapshot_trait_round_trips_rng() {
        let mut rng = Pcg64::seeded(77);
        for _ in 0..13 {
            rng.next_u64();
        }
        let mut w = SnapshotWriter::new();
        rng.save(w.section("rng"));
        let bytes = w.to_bytes();

        let mut reference = rng.clone();
        let mut restored = Pcg64::seeded(0);
        let mut r = SnapshotReader::from_bytes(&bytes).unwrap();
        restored.restore(r.section("rng").unwrap()).unwrap();
        for _ in 0..64 {
            assert_eq!(restored.next_u64(), reference.next_u64());
        }
    }

    #[test]
    fn atomic_write_then_read() {
        let dir = std::env::temp_dir().join(format!("powerctl-snap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.ckpt");
        let w = sample_snapshot();
        w.write_atomic(&path).unwrap();
        // Overwrite with a second snapshot: rename must replace in place.
        let mut w2 = SnapshotWriter::new();
        w2.section("only").put_u64(9);
        w2.write_atomic(&path).unwrap();
        let mut r = SnapshotReader::read(&path).unwrap();
        assert_eq!(r.section_names(), vec!["only"]);
        assert_eq!(r.section("only").unwrap().take_u64().unwrap(), 9);
        assert!(!dir.join("test.ckpt.tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_file_and_garbage_rejected() {
        assert!(SnapshotReader::from_bytes(&[]).is_err());
        assert!(SnapshotReader::from_bytes(&[0u8; 3]).is_err());
        let garbage: Vec<u8> = (0..200u8).collect();
        assert!(SnapshotReader::from_bytes(&garbage).is_err());
    }
}
