//! First-order dynamics: Eq. (3) of the paper.
//!
//! `progress_L(t_{i+1}) = K_L·Δt/(Δt+τ) · pcap_L(t_i) + τ/(Δt+τ) · progress_L(t_i)`
//!
//! Given a static model and a sampled identification run (the §5.1 random
//! powercap signal), this module fits the time constant τ by minimizing the
//! one-step-ahead prediction error, simulates the model forward for the
//! Fig. 5 comparison traces, and reports the error distribution statistics
//! the paper quotes (mean ≈ 0; dispersion grows with socket count).

use crate::ident::lsq::{self, LmOptions};
use crate::ident::static_model::StaticModel;
use crate::util::stats;

/// A sampled identification run: synchronized `(t, pcap, progress)` rows
/// (the coordinator's records, one row per control period).
#[derive(Debug, Clone, Default)]
pub struct SampledRun {
    /// Sample times [s].
    pub times: Vec<f64>,
    /// Cap in force over each transition [W].
    pub pcaps: Vec<f64>,
    /// Measured progress at each sample [Hz].
    pub progress: Vec<f64>,
}

impl SampledRun {
    /// Append one sampled (time, cap, progress) row.
    pub fn push(&mut self, t: f64, pcap: f64, progress: f64) {
        self.times.push(t);
        self.pcaps.push(pcap);
        self.progress.push(progress);
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.times.len()
    }

    /// True when no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }
}

/// The fitted first-order model.
#[derive(Debug, Clone)]
pub struct DynamicModel {
    /// The fitted static characteristic (stage 1).
    pub static_model: StaticModel,
    /// Time constant τ [s].
    pub tau: f64,
    /// RMSE of one-step-ahead prediction on the fitting data [Hz].
    pub rmse: f64,
}

impl DynamicModel {
    /// One-step-ahead prediction of progress at `t_{i+1}` (Eq. 3).
    pub fn predict_next(&self, progress_i: f64, pcap_i: f64, dt: f64) -> f64 {
        let s = &self.static_model;
        let p_l = s.linearize_progress(progress_i);
        let u_l = s.linearize_pcap(pcap_i);
        let denom = dt + self.tau;
        let next_l = s.k_l * dt / denom * u_l + self.tau / denom * p_l;
        next_l + s.k_l
    }

    /// Simulate the model over a sampled run's inputs, starting from the
    /// run's first measured progress (the Fig. 5 "model" trace).
    pub fn simulate(&self, run: &SampledRun) -> Vec<f64> {
        let mut out = Vec::with_capacity(run.len());
        if run.is_empty() {
            return out;
        }
        let mut p = run.progress[0];
        out.push(p);
        for i in 1..run.len() {
            let dt = run.times[i] - run.times[i - 1];
            p = self.predict_next(p, run.pcaps[i - 1], dt);
            out.push(p);
        }
        out
    }

    /// Per-sample model error (measured − simulated) for the Fig. 5
    /// error-distribution panels.
    pub fn errors(&self, run: &SampledRun) -> Vec<f64> {
        self.simulate(run)
            .iter()
            .zip(&run.progress)
            .map(|(sim, meas)| meas - sim)
            .collect()
    }

    /// Fit τ over one or more identification runs, holding the static model
    /// fixed (the paper's procedure: statics first, then dynamics).
    ///
    /// Method: windowed **simulation error** (output-error), not one-step
    /// prediction error. The measured progress carries *colored* noise (OU
    /// modulation, §4.3's socket noise): a one-step predictor can lower its
    /// residual by inflating τ to exploit the noise autocorrelation, which
    /// we observed to bias τ̂ by an order of magnitude on yeti. Simulating
    /// the model over windows from inputs only removes that incentive.
    /// Windows re-anchor at the measured value so a sporadic drop event
    /// (§5.2) only contaminates its own window; a 10 % residual trim then
    /// removes those windows' samples and the model is refit on inliers.
    pub fn fit(static_model: StaticModel, runs: &[SampledRun]) -> DynamicModel {
        const WINDOW: usize = 20;
        let n_res: usize = runs.iter().map(|r| r.len().saturating_sub(1)).sum();
        assert!(n_res >= 8, "need ≥8 transitions to fit tau, got {n_res}");

        // residuals under a candidate tau, with optional per-sample mask.
        let residuals = |tau: f64, mask: Option<&[bool]>, out: &mut Vec<f64>| {
            out.clear();
            let model = DynamicModel {
                static_model: static_model.clone(),
                tau,
                rmse: 0.0,
            };
            let mut k = 0usize;
            for run in runs {
                let mut sim = 0.0;
                for i in 1..run.len() {
                    if (i - 1) % WINDOW == 0 {
                        sim = run.progress[i - 1]; // re-anchor
                    }
                    let dt = run.times[i] - run.times[i - 1];
                    sim = model.predict_next(sim, run.pcaps[i - 1], dt);
                    let include = mask.map(|m| m[k]).unwrap_or(true);
                    out.push(if include { sim - run.progress[i] } else { 0.0 });
                    k += 1;
                }
            }
        };

        let fit_with = |mask: Option<&[bool]>| {
            let mut buf = Vec::with_capacity(n_res);
            lsq::levenberg_marquardt(
                vec![1.0],
                n_res,
                &LmOptions {
                    lower: Some(vec![1e-3]),
                    upper: Some(vec![60.0]),
                    ..Default::default()
                },
                move |prm, out| {
                    residuals(prm[0], mask, &mut buf);
                    out.copy_from_slice(&buf);
                },
            )
        };

        // Pass 1: all samples.
        let first = fit_with(None);
        // Trim the 10 % largest |residual| samples.
        let mut buf = Vec::with_capacity(n_res);
        residuals(first.params[0], None, &mut buf);
        let mut sorted: Vec<f64> = buf.iter().map(|r| r.abs()).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let cutoff = crate::util::stats::quantile_sorted(&sorted, 0.9);
        let mask: Vec<bool> = buf.iter().map(|r| r.abs() <= cutoff).collect();
        let kept = mask.iter().filter(|&&m| m).count();

        // Pass 2: inliers only (fall back if trimming degenerated).
        let (fit, n) = if kept >= 8 {
            (fit_with(Some(&mask)), kept)
        } else {
            (first, n_res)
        };
        DynamicModel {
            static_model,
            tau: fit.params[0],
            rmse: (fit.ssr / n as f64).sqrt(),
        }
    }

    /// Error-distribution summary for EXPERIMENTS.md: (mean, stddev,
    /// min, max) of measured − simulated across runs.
    pub fn error_summary(&self, runs: &[SampledRun]) -> (f64, f64, f64, f64) {
        let mut all = Vec::new();
        for run in runs {
            all.extend(self.errors(run));
        }
        (
            stats::mean(&all),
            stats::stddev(&all),
            all.iter().cloned().fold(f64::INFINITY, f64::min),
            all.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ident::static_model::StaticPoint;
    use crate::sim::cluster::{Cluster, ClusterId};
    use crate::util::rng::Pcg64;

    fn exact_static(id: ClusterId) -> StaticModel {
        let c = Cluster::get(id);
        let points: Vec<StaticPoint> = (0..60)
            .map(|i| {
                let pcap = 40.0 + i as f64 * (80.0 / 59.0);
                StaticPoint {
                    pcap,
                    power: c.expected_power(pcap),
                    progress: c.static_progress(pcap),
                }
            })
            .collect();
        StaticModel::fit(&points)
    }

    /// Generate a sampled run by iterating Eq. (3) with a known τ.
    fn synthetic_run(
        model: &StaticModel,
        tau: f64,
        dt: f64,
        steps: usize,
        noise: f64,
        seed: u64,
    ) -> SampledRun {
        let mut rng = Pcg64::seeded(seed);
        let truth = DynamicModel {
            static_model: model.clone(),
            tau,
            rmse: 0.0,
        };
        let mut run = SampledRun::default();
        let mut p = model.predict(120.0);
        let mut pcap = 120.0;
        for i in 0..steps {
            if i % 17 == 0 {
                pcap = rng.uniform(40.0, 120.0);
            }
            run.push(i as f64 * dt, pcap, p + rng.gauss(0.0, noise));
            p = truth.predict_next(p, pcap, dt);
        }
        run
    }

    #[test]
    fn recovers_tau_noise_free() {
        let s = exact_static(ClusterId::Gros);
        let run = synthetic_run(&s, 1.0 / 3.0, 1.0, 400, 0.0, 1);
        let m = DynamicModel::fit(s, &[run]);
        assert!(
            (m.tau - 1.0 / 3.0).abs() < 0.02,
            "tau {} (want 0.333)",
            m.tau
        );
        assert!(m.rmse < 1e-6);
    }

    #[test]
    fn recovers_tau_with_noise_and_fast_sampling() {
        // τ = 1/3 s needs sub-second sampling to be observable; fit over
        // several noisy runs at 0.2 s.
        let s = exact_static(ClusterId::Dahu);
        let runs: Vec<SampledRun> = (0..4)
            .map(|k| synthetic_run(&s, 1.0 / 3.0, 0.2, 600, 0.3, 10 + k))
            .collect();
        let m = DynamicModel::fit(s, &runs);
        assert!(
            (m.tau - 1.0 / 3.0).abs() < 0.12,
            "tau {} (want 0.333)",
            m.tau
        );
    }

    #[test]
    fn simulate_converges_to_static_prediction() {
        let s = exact_static(ClusterId::Gros);
        let m = DynamicModel {
            static_model: s.clone(),
            tau: 1.0 / 3.0,
            rmse: 0.0,
        };
        let mut run = SampledRun::default();
        for i in 0..120 {
            run.push(i as f64, 60.0, f64::NAN); // inputs only
        }
        run.progress[0] = s.predict(120.0); // start high
        let sim = m.simulate(&run);
        let last = *sim.last().unwrap();
        assert!(
            (last - s.predict(60.0)).abs() < 1e-6,
            "sim settled at {last}, static predicts {}",
            s.predict(60.0)
        );
    }

    #[test]
    fn error_summary_centered_for_true_model() {
        let s = exact_static(ClusterId::Gros);
        let runs: Vec<SampledRun> =
            (0..3).map(|k| synthetic_run(&s, 1.0 / 3.0, 1.0, 300, 0.5, 20 + k)).collect();
        let m = DynamicModel {
            static_model: s,
            tau: 1.0 / 3.0,
            rmse: 0.0,
        };
        let (mean, sd, _, _) = m.error_summary(&runs);
        assert!(mean.abs() < 0.2, "mean {mean}");
        assert!(sd < 2.0, "sd {sd}");
    }

    #[test]
    #[should_panic(expected = "transitions")]
    fn too_short_panics() {
        let s = exact_static(ClusterId::Gros);
        let mut run = SampledRun::default();
        run.push(0.0, 100.0, 20.0);
        DynamicModel::fit(s, &[run]);
    }
}
