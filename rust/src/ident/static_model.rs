//! Static characteristic: fitting and evaluating
//! `progress = K_L · (1 − e^{−α(a·pcap + b − β)})` (paper §4.4, Fig. 4a).
//!
//! The fit runs in two stages, as in the paper:
//!
//! 1. the RAPL accuracy line `power = a·pcap + b` by ordinary least squares
//!    over the (requested cap, measured power) samples;
//! 2. the power→progress saturation curve `(K_L, α, β)` by
//!    Levenberg–Marquardt over the (cap, time-averaged progress) points of
//!    the static-characterization campaign (≥68 runs per cluster).
//!
//! The resulting [`StaticModel`] provides the Eq. (2) linearization used by
//! the controller and the `progress_max` estimate used for the setpoint.

use crate::ident::lsq::{self, LmOptions};
use crate::util::stats;

/// One static-characterization run, reduced to its averages
/// (one Fig. 4a point).
#[derive(Debug, Clone, Copy)]
pub struct StaticPoint {
    /// Requested power cap [W].
    pub pcap: f64,
    /// Time-averaged measured power [W].
    pub power: f64,
    /// Time-averaged progress [Hz].
    pub progress: f64,
}

/// The fitted static model (Table 2's a, b, α, β, K_L for one cluster).
#[derive(Debug, Clone)]
pub struct StaticModel {
    /// RAPL accuracy slope.
    pub a: f64,
    /// RAPL accuracy offset [W].
    pub b: f64,
    /// Exponential shape [1/W].
    pub alpha: f64,
    /// Power offset [W].
    pub beta: f64,
    /// Linear gain / asymptotic progress [Hz].
    pub k_l: f64,
    /// R² of the progress fit over the campaign.
    pub r_squared: f64,
}

impl StaticModel {
    /// Fit from a static-characterization campaign.
    ///
    /// Panics if fewer than 4 points (under-determined) — campaigns in this
    /// repo use ≥68 as in the paper.
    pub fn fit(points: &[StaticPoint]) -> StaticModel {
        assert!(points.len() >= 4, "need ≥4 static points, got {}", points.len());
        // Stage 1: RAPL line.
        let caps: Vec<f64> = points.iter().map(|p| p.pcap).collect();
        let powers: Vec<f64> = points.iter().map(|p| p.power).collect();
        let (a, b) = lsq::linear_fit(&caps, &powers);

        // Stage 2: LM over (power(pcap), progress).
        let progress: Vec<f64> = points.iter().map(|p| p.progress).collect();
        let p_max = progress.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let power_min = powers.iter().cloned().fold(f64::INFINITY, f64::min);
        let init = vec![p_max.max(1.0), 0.03, (power_min - 5.0).max(1.0)];
        let fit = lsq::levenberg_marquardt(
            init,
            points.len(),
            &LmOptions {
                lower: Some(vec![1.0, 1e-4, 0.0]),
                upper: Some(vec![1e4, 1.0, power_min.max(1.0)]),
                ..Default::default()
            },
            |prm, out| {
                for (i, pt) in points.iter().enumerate() {
                    let power = a * pt.pcap + b;
                    let pred = prm[0] * (1.0 - (-prm[1] * (power - prm[2])).exp());
                    out[i] = pred - pt.progress;
                }
            },
        );
        let (k_l, alpha, beta) = (fit.params[0], fit.params[1], fit.params[2]);

        let model = StaticModel {
            a,
            b,
            alpha,
            beta,
            k_l,
            r_squared: 0.0,
        };
        let preds: Vec<f64> = points.iter().map(|p| model.predict(p.pcap)).collect();
        StaticModel {
            r_squared: stats::r_squared(&progress, &preds),
            ..model
        }
    }

    /// Expected measured power for a requested cap.
    pub fn power(&self, pcap: f64) -> f64 {
        self.a * pcap + self.b
    }

    /// Predicted steady-state progress for a requested cap.
    pub fn predict(&self, pcap: f64) -> f64 {
        self.k_l * (1.0 - (-self.alpha * (self.power(pcap) - self.beta)).exp())
    }

    /// Eq. (2): linearized powercap
    /// `pcap_L = −e^{−α(a·pcap + b − β)}` ∈ (−∞, 0).
    ///
    /// The point of the linearization (Fig. 4b): in the transformed
    /// coordinates the saturating plant is exactly linear,
    /// `progress_L = K_L · pcap_L`, and
    /// [`delinearize_pcap`](Self::delinearize_pcap) inverts the transform.
    ///
    /// ```
    /// use powerctl::ident::StaticModel;
    ///
    /// let m = StaticModel {
    ///     a: 0.83, b: 7.07, alpha: 0.047, beta: 28.5, k_l: 25.6,
    ///     r_squared: 1.0,
    /// };
    /// for pcap in [40.0, 87.3, 120.0] {
    ///     // Linearity in the transformed coordinates…
    ///     let lhs = m.linearize_progress(m.predict(pcap));
    ///     let rhs = m.k_l * m.linearize_pcap(pcap);
    ///     assert!((lhs - rhs).abs() < 1e-9);
    ///     // …and the inverse recovers the physical cap.
    ///     let back = m.delinearize_pcap(m.linearize_pcap(pcap));
    ///     assert!((back - pcap).abs() < 1e-9);
    /// }
    /// ```
    pub fn linearize_pcap(&self, pcap: f64) -> f64 {
        -(-self.alpha * (self.power(pcap) - self.beta)).exp()
    }

    /// Eq. (2): linearized progress `progress_L = progress − K_L`.
    pub fn linearize_progress(&self, progress: f64) -> f64 {
        progress - self.k_l
    }

    /// Inverse of [`Self::linearize_pcap`]: recover the physical cap from a
    /// linearized command (the controller's output stage).
    pub fn delinearize_pcap(&self, pcap_l: f64) -> f64 {
        // pcap_L = −e^{−α(a·pcap + b − β)}  ⇒
        // pcap = (β − b − ln(−pcap_L)/α) / a
        let x = (-pcap_l).max(1e-300);
        (self.beta - self.b - x.ln() / self.alpha) / self.a
    }

    /// Estimated maximum progress at the cluster's maximal cap — the
    /// reference the controller multiplies by (1 − ε) (§4.5).
    pub fn progress_max(&self, pcap_max: f64) -> f64 {
        self.predict(pcap_max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cluster::{Cluster, ClusterId};
    use crate::util::rng::Pcg64;

    /// Synthetic campaign straight from a cluster's ground truth + noise.
    fn campaign(id: ClusterId, noise: f64, n: usize, seed: u64) -> Vec<StaticPoint> {
        let c = Cluster::get(id);
        let mut rng = Pcg64::seeded(seed);
        (0..n)
            .map(|i| {
                let pcap = c.pcap_min + (c.pcap_max - c.pcap_min) * (i as f64 / (n - 1) as f64);
                StaticPoint {
                    pcap,
                    power: c.expected_power(pcap) + rng.gauss(0.0, noise * 0.5),
                    progress: c.static_progress(pcap) + rng.gauss(0.0, noise),
                }
            })
            .collect()
    }

    #[test]
    fn exact_recovery_noise_free() {
        for id in ClusterId::ALL {
            let c = Cluster::get(id);
            let m = StaticModel::fit(&campaign(id, 0.0, 80, 1));
            assert!((m.a - c.rapl_a).abs() < 1e-6, "{id} a");
            assert!((m.b - c.rapl_b).abs() < 1e-4, "{id} b");
            assert!((m.k_l - c.k_l).abs() / c.k_l < 1e-3, "{id} K_L: {}", m.k_l);
            assert!((m.alpha - c.alpha).abs() / c.alpha < 0.02, "{id} alpha: {}", m.alpha);
            assert!((m.beta - c.beta).abs() < 1.0, "{id} beta: {}", m.beta);
            assert!(m.r_squared > 0.999, "{id} r2 {}", m.r_squared);
        }
    }

    #[test]
    fn noisy_recovery_within_tolerance() {
        for id in ClusterId::ALL {
            let c = Cluster::get(id);
            let m = StaticModel::fit(&campaign(id, 1.0, 80, 2));
            assert!((m.k_l - c.k_l).abs() / c.k_l < 0.1, "{id} K_L {}", m.k_l);
            // Paper reports 0.83 < R² < 0.95 on real data; synthetic noise
            // at this level stays above that band's floor.
            assert!(m.r_squared > 0.83, "{id} r2 {}", m.r_squared);
        }
    }

    #[test]
    fn linearize_delinearize_roundtrip() {
        let m = StaticModel::fit(&campaign(ClusterId::Gros, 0.0, 40, 3));
        for pcap in [40.0, 55.0, 87.3, 120.0] {
            let back = m.delinearize_pcap(m.linearize_pcap(pcap));
            assert!((back - pcap).abs() < 1e-9, "{pcap} -> {back}");
        }
    }

    #[test]
    fn linearized_progress_is_linear_in_linearized_pcap() {
        // The point of Eq. (2) / Fig. 4b: progress_L = K_L · pcap_L.
        let m = StaticModel::fit(&campaign(ClusterId::Dahu, 0.0, 40, 4));
        for pcap in [45.0, 70.0, 110.0] {
            let lhs = m.linearize_progress(m.predict(pcap));
            let rhs = m.k_l * m.linearize_pcap(pcap);
            assert!((lhs - rhs).abs() < 1e-9);
        }
    }

    #[test]
    fn progress_max_close_to_asymptote() {
        let m = StaticModel::fit(&campaign(ClusterId::Gros, 0.0, 40, 5));
        let pm = m.progress_max(120.0);
        assert!(pm < m.k_l);
        assert!(pm > 0.9 * m.k_l);
    }

    #[test]
    #[should_panic(expected = "≥4")]
    fn too_few_points_panics() {
        StaticModel::fit(&campaign(ClusterId::Gros, 0.0, 3, 6));
    }
}
