//! Least-squares fitting: ordinary linear LSQ and Levenberg–Marquardt
//! nonlinear LSQ, built from scratch (no linear-algebra crate available).
//!
//! The paper fits the RAPL accuracy line (`power = a·pcap + b`) by linear
//! least squares and the static characteristic
//! `progress = K_L(1 − e^{−α(a·pcap + b − β)})` by *nonlinear least
//! squares* (§4.4 "automatically found by using nonlinear least squares").
//! LM with numerical Jacobians is the standard tool; problems here are tiny
//! (≤4 parameters, ≲10³ residuals), so dense Gaussian elimination on the
//! normal equations is ample.

/// Result of a fit.
#[derive(Debug, Clone)]
pub struct FitResult {
    /// Fitted parameter vector.
    pub params: Vec<f64>,
    /// Final sum of squared residuals.
    pub ssr: f64,
    /// Iterations used.
    pub iterations: usize,
    /// Whether the tolerance criterion was met (vs iteration cap).
    pub converged: bool,
}

/// Ordinary least squares for `y ≈ a·x + b`; returns `(a, b)`.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points");
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    assert!(
        denom.abs() > 1e-12,
        "degenerate design matrix (all x identical)"
    );
    let a = (n * sxy - sx * sy) / denom;
    let b = (sy - a * sx) / n;
    (a, b)
}

/// Solve the square system `A·x = b` in place by Gaussian elimination with
/// partial pivoting. `a` is row-major `n×n`. Returns `None` if singular.
pub fn solve(a: &mut [f64], b: &mut [f64], n: usize) -> Option<Vec<f64>> {
    debug_assert_eq!(a.len(), n * n);
    debug_assert_eq!(b.len(), n);
    for col in 0..n {
        // Pivot.
        let mut pivot = col;
        for row in col + 1..n {
            if a[row * n + col].abs() > a[pivot * n + col].abs() {
                pivot = row;
            }
        }
        if a[pivot * n + col].abs() < 1e-12 {
            return None;
        }
        if pivot != col {
            for k in 0..n {
                a.swap(col * n + k, pivot * n + k);
            }
            b.swap(col, pivot);
        }
        // Eliminate below.
        let diag = a[col * n + col];
        for row in col + 1..n {
            let f = a[row * n + col] / diag;
            if f == 0.0 {
                continue;
            }
            for k in col..n {
                a[row * n + k] -= f * a[col * n + k];
            }
            b[row] -= f * b[col];
        }
    }
    // Back substitution.
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for k in row + 1..n {
            acc -= a[row * n + k] * x[k];
        }
        x[row] = acc / a[row * n + row];
    }
    Some(x)
}

/// Options for [`levenberg_marquardt`].
#[derive(Debug, Clone)]
pub struct LmOptions {
    /// Iteration budget of the LM loop.
    pub max_iterations: usize,
    /// Stop when the relative SSR improvement falls below this.
    pub tolerance: f64,
    /// Initial damping factor.
    pub lambda0: f64,
    /// Optional per-parameter lower/upper bounds (projected after each step).
    pub lower: Option<Vec<f64>>,
    /// Optional per-parameter upper bounds.
    pub upper: Option<Vec<f64>>,
}

impl Default for LmOptions {
    fn default() -> Self {
        LmOptions {
            max_iterations: 200,
            tolerance: 1e-12,
            lambda0: 1e-3,
            lower: None,
            upper: None,
        }
    }
}

fn clamp_params(p: &mut [f64], opts: &LmOptions) {
    if let Some(lo) = &opts.lower {
        for (x, &l) in p.iter_mut().zip(lo) {
            *x = x.max(l);
        }
    }
    if let Some(hi) = &opts.upper {
        for (x, &u) in p.iter_mut().zip(hi) {
            *x = x.min(u);
        }
    }
}

/// Levenberg–Marquardt minimization of `Σᵢ residual(params, i)²`.
///
/// `residuals(params, out)` fills `out` with the residual vector. The
/// Jacobian is estimated by central finite differences.
pub fn levenberg_marquardt<F>(
    mut params: Vec<f64>,
    n_residuals: usize,
    opts: &LmOptions,
    mut residuals: F,
) -> FitResult
where
    F: FnMut(&[f64], &mut [f64]),
{
    let np = params.len();
    clamp_params(&mut params, opts);
    let mut r = vec![0.0; n_residuals];
    let mut r_trial = vec![0.0; n_residuals];
    let mut jac = vec![0.0; n_residuals * np]; // row-major: residual × param
    let mut lambda = opts.lambda0;

    residuals(&params, &mut r);
    let mut ssr: f64 = r.iter().map(|x| x * x).sum();
    let mut converged = false;
    let mut iter = 0;

    while iter < opts.max_iterations {
        iter += 1;
        // Numerical Jacobian (central differences, parameter-scaled h).
        let mut rp = vec![0.0; n_residuals];
        let mut rm = vec![0.0; n_residuals];
        for j in 0..np {
            let h = 1e-6 * params[j].abs().max(1e-4);
            let mut pp = params.clone();
            pp[j] += h;
            residuals(&pp, &mut rp);
            pp[j] = params[j] - h;
            residuals(&pp, &mut rm);
            for i in 0..n_residuals {
                jac[i * np + j] = (rp[i] - rm[i]) / (2.0 * h);
            }
        }
        // Normal equations: (JᵀJ + λ·diag(JᵀJ))·δ = -Jᵀr.
        let mut jtj = vec![0.0; np * np];
        let mut jtr = vec![0.0; np];
        for i in 0..n_residuals {
            for a in 0..np {
                let ja = jac[i * np + a];
                jtr[a] -= ja * r[i];
                for b in a..np {
                    jtj[a * np + b] += ja * jac[i * np + b];
                }
            }
        }
        for a in 0..np {
            for b in 0..a {
                jtj[a * np + b] = jtj[b * np + a];
            }
        }

        // Try damped steps, increasing λ on failure.
        let mut improved = false;
        for _ in 0..16 {
            let mut a = jtj.clone();
            let mut b = jtr.clone();
            for d in 0..np {
                a[d * np + d] += lambda * jtj[d * np + d].max(1e-12);
            }
            let Some(delta) = solve(&mut a, &mut b, np) else {
                lambda *= 10.0;
                continue;
            };
            let mut trial: Vec<f64> = params
                .iter()
                .zip(&delta)
                .map(|(p, d)| p + d)
                .collect();
            clamp_params(&mut trial, opts);
            residuals(&trial, &mut r_trial);
            let ssr_trial: f64 = r_trial.iter().map(|x| x * x).sum();
            if ssr_trial.is_finite() && ssr_trial < ssr {
                let rel = (ssr - ssr_trial) / ssr.max(1e-300);
                params = trial;
                std::mem::swap(&mut r, &mut r_trial);
                ssr = ssr_trial;
                lambda = (lambda * 0.3).max(1e-12);
                improved = true;
                if rel < opts.tolerance {
                    converged = true;
                }
                break;
            }
            lambda *= 10.0;
            if lambda > 1e12 {
                break;
            }
        }
        if !improved {
            converged = true; // stuck at a (local) minimum
            break;
        }
        if converged {
            break;
        }
    }

    FitResult {
        params,
        ssr,
        iterations: iter,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn linear_fit_exact() {
        let xs = [40.0, 60.0, 80.0, 100.0, 120.0];
        let ys: Vec<f64> = xs.iter().map(|x| 0.83 * x + 7.07).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 0.83).abs() < 1e-12);
        assert!((b - 7.07).abs() < 1e-10);
    }

    #[test]
    fn linear_fit_noisy() {
        let mut rng = Pcg64::seeded(1);
        let xs: Vec<f64> = (0..500).map(|_| rng.uniform(40.0, 120.0)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 0.94 * x + 0.17 + rng.gauss(0.0, 1.0)).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 0.94).abs() < 0.01, "a={a}");
        assert!((b - 0.17).abs() < 1.0, "b={b}");
    }

    #[test]
    #[should_panic(expected = "degenerate")]
    fn linear_fit_degenerate() {
        linear_fit(&[1.0, 1.0], &[2.0, 3.0]);
    }

    #[test]
    fn solve_3x3() {
        // A = [[2,1,0],[1,3,1],[0,1,2]], x = [1,2,3] → b = [4,10,8]
        let mut a = vec![2.0, 1.0, 0.0, 1.0, 3.0, 1.0, 0.0, 1.0, 2.0];
        let mut b = vec![4.0, 10.0, 8.0];
        let x = solve(&mut a, &mut b, 3).unwrap();
        for (got, want) in x.iter().zip([1.0, 2.0, 3.0]) {
            assert!((got - want).abs() < 1e-12);
        }
    }

    #[test]
    fn solve_singular_none() {
        let mut a = vec![1.0, 2.0, 2.0, 4.0];
        let mut b = vec![1.0, 2.0];
        assert!(solve(&mut a, &mut b, 2).is_none());
    }

    #[test]
    fn lm_fits_exponential_saturation() {
        // The exact model family of the paper's static characteristic.
        let truth = [25.6, 0.047, 28.5]; // K_L, alpha, beta
        let powers: Vec<f64> = (0..60).map(|i| 40.0 + i as f64 * 1.2).collect();
        let obs: Vec<f64> = powers
            .iter()
            .map(|&p| truth[0] * (1.0 - (-truth[1] * (p - truth[2])).exp()))
            .collect();
        let fit = levenberg_marquardt(
            vec![10.0, 0.02, 20.0],
            powers.len(),
            &LmOptions {
                lower: Some(vec![1.0, 1e-4, 0.0]),
                upper: Some(vec![500.0, 1.0, 60.0]),
                ..Default::default()
            },
            |p, out| {
                for (i, &pw) in powers.iter().enumerate() {
                    let pred = p[0] * (1.0 - (-p[1] * (pw - p[2])).exp());
                    out[i] = pred - obs[i];
                }
            },
        );
        assert!(fit.converged, "{fit:?}");
        for (got, want) in fit.params.iter().zip(truth) {
            assert!(
                (got - want).abs() / want < 1e-3,
                "params {:?} vs {truth:?}",
                fit.params
            );
        }
    }

    #[test]
    fn lm_fits_under_noise() {
        let mut rng = Pcg64::seeded(2);
        let truth = [78.5, 0.023, 33.7];
        let powers: Vec<f64> = (0..300).map(|_| rng.uniform(38.0, 110.0)).collect();
        let obs: Vec<f64> = powers
            .iter()
            .map(|&p| {
                truth[0] * (1.0 - (-truth[1] * (p - truth[2])).exp()) + rng.gauss(0.0, 2.0)
            })
            .collect();
        let fit = levenberg_marquardt(
            vec![50.0, 0.05, 25.0],
            powers.len(),
            &LmOptions {
                lower: Some(vec![1.0, 1e-4, 0.0]),
                upper: Some(vec![500.0, 1.0, 60.0]),
                ..Default::default()
            },
            |p, out| {
                for (i, &pw) in powers.iter().enumerate() {
                    out[i] = p[0] * (1.0 - (-p[1] * (pw - p[2])).exp()) - obs[i];
                }
            },
        );
        for (got, want) in fit.params.iter().zip(truth) {
            assert!(
                (got - want).abs() / want < 0.15,
                "params {:?} vs {truth:?}",
                fit.params
            );
        }
    }

    #[test]
    fn lm_respects_bounds() {
        let obs = [1.0, 2.0, 3.0];
        let fit = levenberg_marquardt(
            vec![5.0],
            3,
            &LmOptions {
                lower: Some(vec![4.0]),
                upper: Some(vec![10.0]),
                ..Default::default()
            },
            |p, out| {
                for (i, o) in obs.iter().enumerate() {
                    out[i] = p[0] - o;
                }
            },
        );
        // Unconstrained optimum is mean=2, but the bound holds at 4.
        assert!((fit.params[0] - 4.0).abs() < 1e-6, "{:?}", fit.params);
    }

    #[test]
    fn lm_handles_already_optimal() {
        let fit = levenberg_marquardt(vec![2.0], 3, &LmOptions::default(), |p, out| {
            for (i, o) in [1.0, 2.0, 3.0].iter().enumerate() {
                out[i] = p[0] - o;
            }
        });
        assert!((fit.params[0] - 2.0).abs() < 1e-9);
        assert!(fit.converged);
    }
}
