//! Excitation signal generators for the identification campaigns.
//!
//! * [`staircase`] — the §4.3 system-analysis plan: the cap is gradually
//!   increased in 20 W steps over the cluster's reasonable range (Fig. 3);
//! * [`constant`] — the static-characterization plan: one constant cap for
//!   the whole run (each Fig. 4 point is one such run);
//! * [`random_steps`] — the §5.1 model-accuracy plan: a piecewise-constant
//!   signal with random magnitude (40–120 W) and random switching frequency
//!   (10⁻²–1 Hz) (Fig. 5).
//!
//! All generators produce a [`Plan`]: a zero-order-hold powercap schedule
//! executed open-loop by the coordinator's characterization mode.

use crate::util::rng::Pcg64;
use crate::util::timeseries::TimeSeries;

/// An open-loop powercap schedule (zero-order hold between points) with a
/// total duration.
#[derive(Debug, Clone)]
pub struct Plan {
    /// Cap changes: `(time [s], pcap [W])`, starting at t = 0.
    pub schedule: TimeSeries,
    /// Total duration of the run [s].
    pub duration: f64,
}

impl Plan {
    /// The cap in force at time `t`.
    pub fn pcap_at(&self, t: f64) -> f64 {
        self.schedule
            .zoh(t)
            .unwrap_or_else(|| self.schedule.values[0])
    }

    /// Number of distinct levels.
    pub fn levels(&self) -> usize {
        self.schedule.len()
    }
}

/// Constant-cap plan (static characterization: one Fig. 4 point per run).
pub fn constant(pcap: f64, duration: f64) -> Plan {
    let mut schedule = TimeSeries::new();
    schedule.push(0.0, pcap);
    Plan { schedule, duration }
}

/// §4.3 staircase: from `lo` to `hi` in `step` increments, holding each
/// level for `hold` seconds (Fig. 3 uses 40→120 W by 20 W).
///
/// ```
/// use powerctl::ident::signals::staircase;
///
/// // The paper's Fig. 3 plan: five 20 W levels held 20 s each.
/// let plan = staircase(40.0, 120.0, 20.0, 20.0);
/// assert_eq!(plan.levels(), 5);
/// assert_eq!(plan.pcap_at(0.0), 40.0);   // first level…
/// assert_eq!(plan.pcap_at(20.0), 60.0);  // …steps up at each hold boundary
/// assert_eq!(plan.duration, 100.0);
/// ```
pub fn staircase(lo: f64, hi: f64, step: f64, hold: f64) -> Plan {
    assert!(step > 0.0 && hi >= lo && hold > 0.0);
    let mut schedule = TimeSeries::new();
    let mut level = lo;
    let mut t = 0.0;
    while level <= hi + 1e-9 {
        schedule.push(t, level.min(hi));
        t += hold;
        level += step;
    }
    Plan {
        schedule,
        duration: t,
    }
}

/// §5.1 random-step excitation: piecewise-constant caps with magnitudes
/// uniform in `[lo, hi]` and dwell times drawn so switching frequency spans
/// `[f_min, f_max]` (log-uniform, capturing both slow and fast dynamics).
pub fn random_steps(
    lo: f64,
    hi: f64,
    f_min: f64,
    f_max: f64,
    duration: f64,
    rng: &mut Pcg64,
) -> Plan {
    assert!(hi > lo && f_max > f_min && f_min > 0.0 && duration > 0.0);
    let mut schedule = TimeSeries::new();
    let mut t = 0.0;
    while t < duration {
        let pcap = rng.uniform(lo, hi);
        // Log-uniform switching frequency → dwell = 1/f.
        let logf = rng.uniform(f_min.ln(), f_max.ln());
        let dwell = 1.0 / logf.exp();
        schedule.push(t, pcap);
        t += dwell;
    }
    Plan {
        schedule,
        duration,
    }
}

/// Pseudo-random binary sequence between two levels — a classic
/// system-identification excitation used by the ablation benches to compare
/// identification quality across excitation shapes.
pub fn prbs(lo: f64, hi: f64, bit: f64, duration: f64, rng: &mut Pcg64) -> Plan {
    assert!(hi > lo && bit > 0.0);
    let mut schedule = TimeSeries::new();
    let mut t = 0.0;
    while t < duration {
        let level = if rng.next_u32() & 1 == 0 { lo } else { hi };
        schedule.push(t, level);
        t += bit;
    }
    Plan { schedule, duration }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staircase_matches_paper_plan() {
        // 40→120 W by 20 W: five levels.
        let p = staircase(40.0, 120.0, 20.0, 20.0);
        assert_eq!(p.levels(), 5);
        assert_eq!(p.pcap_at(0.0), 40.0);
        assert_eq!(p.pcap_at(19.9), 40.0);
        assert_eq!(p.pcap_at(20.0), 60.0);
        assert_eq!(p.pcap_at(99.0), 120.0);
        assert_eq!(p.duration, 100.0);
    }

    #[test]
    fn constant_plan() {
        let p = constant(80.0, 300.0);
        assert_eq!(p.pcap_at(0.0), 80.0);
        assert_eq!(p.pcap_at(299.0), 80.0);
        assert_eq!(p.levels(), 1);
    }

    #[test]
    fn random_steps_in_ranges() {
        let mut rng = Pcg64::seeded(1);
        let p = random_steps(40.0, 120.0, 1e-2, 1.0, 600.0, &mut rng);
        assert!(p.levels() > 5);
        for (i, (&t, &v)) in p.schedule.times.iter().zip(&p.schedule.values).enumerate() {
            assert!((40.0..=120.0).contains(&v), "level {v}");
            if i > 0 {
                let dwell = t - p.schedule.times[i - 1];
                assert!(
                    (0.99..=101.0).contains(&dwell),
                    "dwell {dwell} outside [1,100] s"
                );
            }
        }
    }

    #[test]
    fn random_steps_deterministic() {
        let mut r1 = Pcg64::seeded(2);
        let mut r2 = Pcg64::seeded(2);
        let p1 = random_steps(40.0, 120.0, 1e-2, 1.0, 300.0, &mut r1);
        let p2 = random_steps(40.0, 120.0, 1e-2, 1.0, 300.0, &mut r2);
        assert_eq!(p1.schedule, p2.schedule);
    }

    #[test]
    fn prbs_two_levels() {
        let mut rng = Pcg64::seeded(3);
        let p = prbs(40.0, 120.0, 5.0, 200.0, &mut rng);
        assert!(p.schedule.values.iter().all(|&v| v == 40.0 || v == 120.0));
        assert!(p.schedule.values.iter().any(|&v| v == 40.0));
        assert!(p.schedule.values.iter().any(|&v| v == 120.0));
    }
}
