//! System identification (paper §4.3–4.4, Figs. 3–5, Table 2).
//!
//! The offline workflow: run open-loop campaigns (excitation signals from
//! [`signals`]), reduce each run to the [`static_model`] points or the
//! [`dynamic_model`] sampled traces, and fit with the from-scratch
//! least-squares machinery in [`lsq`]. Fitted models — never the
//! simulator's ground truth — parameterize the controller.

pub mod dynamic_model;
pub mod lsq;
pub mod signals;
pub mod static_model;

pub use dynamic_model::{DynamicModel, SampledRun};
pub use signals::Plan;
pub use static_model::{StaticModel, StaticPoint};
