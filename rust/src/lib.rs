//! # powerctl — control-theoretic power regulation for HPC nodes
//!
//! Reproduction of Cerf et al., *"Sustaining Performance While Reducing
//! Energy Consumption: A Control Theory Approach"* (Euro-Par 2021): a PI
//! controller tracks an application-progress setpoint by actuating the RAPL
//! power cap, saving energy on memory-bound phases with a user-chosen
//! performance-degradation budget ε.
//!
//! The crate is the L3 (Rust) layer of a three-layer stack:
//!
//! * **L1** — Pallas STREAM kernels (`python/compile/kernels/`), AOT-lowered,
//! * **L2** — JAX compute graph (`python/compile/model.py`) → HLO text
//!   artifacts,
//! * **L3** — this crate: the NRM-style coordinator built around a single
//!   [`ControlLoop`](coordinator::engine::ControlLoop) engine, the PI
//!   controller, the simulated Grid'5000 substrate, the identification
//!   pipeline, the evaluation harness, and the [`fleet`] layer that scales
//!   the loop to N nodes under one global power budget. Python never runs
//!   on the control path.
//!
//! Control is **hierarchical** (device → node → fleet): a node may carry
//! several devices (CPU + GPU — [`sim::device`]), each under its own PI
//! below a movable ceiling; the node splits its cap across devices
//! ([`control::node_budget`] behind
//! [`HeteroBackend`](coordinator::hetero::HeteroBackend)), and the fleet
//! splits the global budget across nodes ([`control::budget`]). A
//! single-device node collapses to the paper's loop, byte for byte.
//!
//! See `README.md` for the quickstart and subcommand map, `DESIGN.md` for
//! the system inventory, `EXPERIMENTS.md` for paper-vs-measured results,
//! and `docs/API.md` for the committed API reference.

#![warn(missing_docs)]

pub mod control;
pub mod coordinator;
pub mod experiments;
pub mod fleet;
pub mod ident;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;
