//! Property tests for `util::json`: value → text → value round-trips over
//! randomly generated documents (nested containers, escape-heavy strings,
//! number edge cases), plus rejection of malformed input.

use std::collections::BTreeMap;

use powerctl::util::check::{check, Verdict};
use powerctl::util::json::Json;
use powerctl::util::rng::Pcg64;

/// Characters that exercise every escape path in the writer/parser.
const STRING_PALETTE: &[char] = &[
    'a', 'Z', '0', ' ', '"', '\\', '\n', '\r', '\t', '\u{8}', '\u{c}', '\u{1}', '\u{1f}', '/',
    'é', '∀', '😀', '\u{7f}', 'µ',
];

fn random_string(rng: &mut Pcg64) -> String {
    let len = rng.below(12) as usize;
    (0..len)
        .map(|_| *rng.choose(STRING_PALETTE))
        .collect()
}

fn random_number(rng: &mut Pcg64) -> f64 {
    match rng.below(8) {
        0 => 0.0,
        1 => -0.0,
        2 => rng.below(2_000_000) as f64 - 1_000_000.0, // integral
        3 => 1e15 + rng.below(1_000_000) as f64,        // beyond the i64 fast path
        4 => f64::MAX * (rng.f64() - 0.5),
        5 => 5e-324 * (1.0 + rng.below(100) as f64),    // subnormals
        _ => loop {
            // Uniform over bit patterns, rejecting non-finite values (JSON
            // cannot represent them).
            let x = f64::from_bits(rng.next_u64());
            if x.is_finite() {
                break x;
            }
        },
    }
}

fn random_json(rng: &mut Pcg64, depth: u32) -> Json {
    let top = if depth == 0 { 4 } else { 6 };
    match rng.below(top) {
        0 => Json::Null,
        1 => Json::Bool(rng.f64() < 0.5),
        2 => Json::Num(random_number(rng)),
        3 => Json::Str(random_string(rng)),
        4 => {
            let n = rng.below(5) as usize;
            Json::Arr((0..n).map(|_| random_json(rng, depth - 1)).collect())
        }
        _ => {
            let n = rng.below(5) as usize;
            let mut m = BTreeMap::new();
            for _ in 0..n {
                m.insert(random_string(rng), random_json(rng, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn prop_compact_roundtrip() {
    check(7001, 300, |rng| random_json(rng, 4), |v| {
        let text = v.dump();
        match Json::parse(&text) {
            Ok(back) if back == *v => Verdict::Pass,
            Ok(back) => Verdict::Fail(format!("{back:?} != original (text: {text})")),
            Err(e) => Verdict::Fail(format!("reparse failed: {e} (text: {text})")),
        }
    });
}

#[test]
fn prop_pretty_roundtrip() {
    check(7002, 200, |rng| random_json(rng, 3), |v| {
        match Json::parse(&v.pretty()) {
            Ok(back) if back == *v => Verdict::Pass,
            Ok(_) => Verdict::Fail("pretty reparse differs".to_string()),
            Err(e) => Verdict::Fail(format!("pretty reparse failed: {e}")),
        }
    });
}

#[test]
fn prop_numbers_roundtrip_exactly() {
    check(7003, 2000, |rng| random_number(rng), |&x| {
        let v = Json::Num(x);
        match Json::parse(&v.dump()) {
            // -0.0 == 0.0 under PartialEq, which is the contract we need.
            Ok(Json::Num(y)) if y == x => Verdict::Pass,
            Ok(other) => Verdict::Fail(format!("{x} → {other:?}")),
            Err(e) => Verdict::Fail(format!("{x}: {e}")),
        }
    });
}

#[test]
fn prop_escape_heavy_strings_roundtrip() {
    check(7004, 500, |rng| random_string(rng), |s| {
        let v = Json::Str(s.clone());
        match Json::parse(&v.dump()) {
            Ok(Json::Str(back)) if back == *s => Verdict::Pass,
            Ok(other) => Verdict::Fail(format!("{s:?} → {other:?}")),
            Err(e) => Verdict::Fail(format!("{s:?}: {e}")),
        }
    });
}

#[test]
fn prop_container_prefixes_rejected() {
    // Every strict prefix of a serialized container is malformed: the
    // parser must reject it rather than return a partial value.
    check(7005, 100, |rng| {
        let v = loop {
            let v = random_json(rng, 3);
            if matches!(v, Json::Arr(_) | Json::Obj(_)) {
                break v;
            }
        };
        let text = v.dump();
        let cut = 1 + rng.below((text.len() - 1) as u64) as usize;
        (text, cut)
    }, |(text, cut)| {
        // Cut on a char boundary (multi-byte palette chars).
        let mut cut = *cut;
        while !text.is_char_boundary(cut) {
            cut -= 1;
        }
        if cut == 0 {
            return Verdict::Discard;
        }
        let prefix = &text[..cut];
        match Json::parse(prefix) {
            Err(_) => Verdict::Pass,
            Ok(v) => Verdict::Fail(format!("prefix {prefix:?} parsed as {v:?}")),
        }
    });
}

#[test]
fn malformed_documents_rejected() {
    for text in [
        "",
        "  ",
        "{",
        "}",
        "[1,",
        "[1 2]",
        "{\"a\" 1}",
        "{\"a\":}",
        "{a:1}",
        "tru",
        "nul",
        "falsey",
        "1 2",
        "\"unterminated",
        "\"bad \\q escape\"",
        "\"trunc \\u12",
        "[1,]2",
        "{\"a\":1}}",
        "--1",
        "+1",
        "01x",
    ] {
        assert!(Json::parse(text).is_err(), "accepted malformed: {text:?}");
    }
}

#[test]
fn hetero_run_record_roundtrips() {
    // A RunRecord with per-device traces (the hierarchical-node export)
    // must round-trip through the JSON layer value-exactly, and the
    // "devices" key must appear iff traces are present.
    use powerctl::coordinator::records::{DeviceTrace, RunRecord};

    let mut rng = Pcg64::seeded(7100);
    let mut rec = RunRecord {
        cluster: "gros".into(),
        policy: "hetero-slack-shift-eps0.15".into(),
        node_id: 2,
        seed: 99,
        epsilon: 0.15,
        setpoint: f64::NAN, // non-finite scalars serialize as null
        exec_time: 87.3,
        energy: 12_345.6,
        beats: 2_600,
        completed: true,
        ..Default::default()
    };
    for kind in ["cpu", "gpu"] {
        let mut d = DeviceTrace {
            kind: kind.into(),
            ..Default::default()
        };
        for i in 0..40 {
            let t = i as f64;
            rec_push(&mut d.pcap, t, rng.uniform(40.0, 400.0));
            rec_push(&mut d.power, t, rng.uniform(30.0, 390.0));
            rec_push(&mut d.progress, t, rng.uniform(0.0, 120.0));
        }
        rec.devices.push(d);
    }
    for i in 0..40 {
        let t = i as f64;
        rec_push(&mut rec.pcap, t, rng.uniform(140.0, 520.0));
        rec_push(&mut rec.power, t, rng.uniform(100.0, 500.0));
        rec_push(&mut rec.progress, t, rng.uniform(0.0, 140.0));
        rec_push(&mut rec.true_progress, t, f64::NAN);
    }

    let j = rec.to_json();
    let back = Json::parse(&j.dump()).unwrap();
    assert_eq!(back, j);
    let back_pretty = Json::parse(&j.pretty()).unwrap();
    assert_eq!(back_pretty, j);
    let devs = back.get("devices").unwrap().as_arr().unwrap();
    assert_eq!(devs.len(), 2);
    assert_eq!(
        devs[1].get_path(&["pcap", "values"]).unwrap().as_arr().unwrap().len(),
        40
    );

    // Single-device records must not grow the key (byte-compat contract).
    rec.devices.clear();
    assert!(rec.to_json().get("devices").is_none());
}

fn rec_push(ts: &mut powerctl::util::timeseries::TimeSeries, t: f64, v: f64) {
    ts.push(t, v);
}

#[test]
fn deep_nesting_roundtrips() {
    let mut v = Json::Num(1.0);
    for i in 0..64 {
        if i % 2 == 0 {
            v = Json::Arr(vec![v]);
        } else {
            let mut m = BTreeMap::new();
            m.insert("k".to_string(), v);
            v = Json::Obj(m);
        }
    }
    let back = Json::parse(&v.dump()).unwrap();
    assert_eq!(back, v);
    let back = Json::parse(&v.pretty()).unwrap();
    assert_eq!(back, v);
}
