//! Single-device equivalence: the multi-device refactor must be invisible
//! for the paper's single-processor node.
//!
//! Two independent pins:
//!
//! 1. **Sim layer** — `NodeSim::new` (now a one-CPU-device composition)
//!    produces the same heartbeats/sensors as an explicit one-device
//!    `NodeSim::hetero`, and the classic campaign adapters on top of it
//!    reproduce the pre-refactor records (`tests/pipeline.rs` and the fleet
//!    equivalence suite cover those paths at scale).
//! 2. **Control layer** — a run driven through the *hierarchical* path
//!    (`HeteroBackend` + degenerate one-device split) emits `RunRecord`
//!    JSON **byte-identical** to the classic `run_closed_loop` path: same
//!    series, same scalars, no `devices` key. The hierarchy collapses
//!    cleanly; refactoring under it is safe.

use powerctl::control::baseline::{PiPolicy, Policy, Uncontrolled};
use powerctl::control::node_budget::{DeviceCtl, DeviceSplitSpec, NodeBudgetController};
use powerctl::control::pi::{PiConfig, PiController};
use powerctl::coordinator::engine::ControlLoop;
use powerctl::coordinator::experiment::{run_closed_loop, RunConfig};
use powerctl::coordinator::hetero::HeteroBackend;
use powerctl::coordinator::records::RunRecord;
use powerctl::fleet::node::noise_free_model;
use powerctl::sim::cluster::{Cluster, ClusterId};
use powerctl::sim::device::DeviceSpec;
use powerctl::sim::node::NodeSim;

fn config() -> RunConfig {
    RunConfig {
        sample_period: 1.0,
        total_beats: 1_200,
        max_time: 600.0,
    }
}

/// Drive one single-device node through the hierarchical path with the
/// given outer policy; mirror `run_closed_loop`'s scalar finalization.
fn run_hetero_single(
    id: ClusterId,
    policy: &mut dyn Policy,
    setpoint: f64,
    epsilon: f64,
    cfg: &RunConfig,
    seed: u64,
) -> RunRecord {
    let cluster = Cluster::get(id);
    let cpu = DeviceSpec::cpu(&cluster);
    let node = NodeSim::hetero(cluster.clone(), &[cpu.clone()], seed);
    // Degenerate inner loop: an even split over one pinned device reduces
    // to "device cap = clamped node cap" — exactly the classic actuator.
    let ctl = NodeBudgetController::new(
        DeviceSplitSpec::Even.build(),
        vec![DeviceCtl::pinned(&cpu, cpu.cap_max)],
    );
    let mut engine = ControlLoop::new(HeteroBackend::new(node, ctl), cfg.sample_period);
    engine.set_initial_pcap(cluster.pcap_max);
    engine.set_quota(Some(cfg.total_beats));
    engine.set_max_time(cfg.max_time);
    let mut clock = powerctl::sim::VirtualClock::new();
    engine.run(&mut clock, policy, None);

    let mut rec = engine.record();
    rec.cluster = cluster.id.name().to_string();
    rec.policy = policy.name();
    rec.seed = seed;
    rec.epsilon = epsilon;
    rec.setpoint = setpoint;
    rec.completed = engine.finish_time().is_some();
    rec.exec_time = engine.finish_time().unwrap_or(cfg.max_time);
    rec.beats = engine.total_beats().min(cfg.total_beats);
    rec
}

#[test]
fn hierarchical_single_device_run_is_byte_identical_uncontrolled() {
    let cfg = config();
    for (id, seed) in [(ClusterId::Gros, 3u64), (ClusterId::Dahu, 4), (ClusterId::Yeti, 5)] {
        let cluster = Cluster::get(id);
        let mut p1 = Uncontrolled { pcap_max: cluster.pcap_max };
        let classic = run_closed_loop(&cluster, &mut p1, f64::NAN, 0.0, &cfg, seed);
        let mut p2 = Uncontrolled { pcap_max: cluster.pcap_max };
        let hetero = run_hetero_single(id, &mut p2, f64::NAN, 0.0, &cfg, seed);
        assert!(
            classic.to_json().dump() == hetero.to_json().dump(),
            "{id}: hierarchical single-device record differs from classic"
        );
        assert!(classic.devices.is_empty() && hetero.devices.is_empty());
    }
}

#[test]
fn hierarchical_single_device_run_is_byte_identical_under_pi() {
    // The discriminating case: a *feedback* policy means any divergence in
    // measured progress or applied caps compounds — byte equality proves
    // the whole sense → Eq. (1) → PI → actuate chain is untouched.
    let cfg = config();
    let id = ClusterId::Gros;
    let cluster = Cluster::get(id);
    let model = noise_free_model(id);
    let make_pi = || {
        let pic = PiConfig::from_model(&model, 10.0, cluster.pcap_min, cluster.pcap_max);
        PiController::new(model.clone(), pic, 0.15)
    };
    let sp = make_pi().setpoint();

    let mut p1 = PiPolicy(make_pi());
    let classic = run_closed_loop(&cluster, &mut p1, sp, 0.15, &cfg, 42);
    let mut p2 = PiPolicy(make_pi());
    let hetero = run_hetero_single(id, &mut p2, sp, 0.15, &cfg, 42);

    assert!(classic.completed, "closed loop must complete");
    assert!(
        classic.to_json().dump() == hetero.to_json().dump(),
        "hierarchical single-device PI record differs from classic"
    );
}

#[test]
fn sim_layer_single_device_composition_is_invisible() {
    // NodeSim::new == one-CPU NodeSim::hetero, step for step.
    for id in [ClusterId::Gros, ClusterId::Dahu, ClusterId::Yeti] {
        let cluster = Cluster::get(id);
        let mut classic = NodeSim::new(cluster.clone(), 77);
        let mut composed = NodeSim::hetero(cluster.clone(), &[DeviceSpec::cpu(&cluster)], 77);
        classic.set_pcap(90.0);
        composed.set_pcap(90.0);
        for _ in 0..60 {
            let a = classic.step(1.0);
            let b = composed.step(1.0);
            assert_eq!(a.power, b.power, "{id}");
            assert_eq!(a.energy, b.energy, "{id}");
            assert_eq!(a.pcap, b.pcap, "{id}");
            assert_eq!(a.heartbeats, b.heartbeats, "{id}");
        }
    }
}

#[test]
fn multi_device_records_are_deterministic_and_discriminated() {
    // The non-degenerate hierarchy: same seed → same bytes; different seed
    // → different bytes (the JSON oracle has discriminating power over
    // device traces too).
    use powerctl::control::baseline::StaticCap;
    use powerctl::control::node_budget::ideal_device_model;

    let run = |seed: u64| {
        let cluster = Cluster::get(ClusterId::Gros);
        let cpu = DeviceSpec::cpu(&cluster);
        let gpu = DeviceSpec::gpu();
        let node = NodeSim::hetero(cluster, &[cpu.clone(), gpu.clone()], seed);
        let ctl = NodeBudgetController::new(
            DeviceSplitSpec::SlackShift.build(),
            vec![
                DeviceCtl::pi(&cpu, ideal_device_model(&cpu), 0.15, cpu.cap_max),
                DeviceCtl::pi(&gpu, ideal_device_model(&gpu), 0.15, gpu.cap_max),
            ],
        );
        let mut engine = ControlLoop::new(HeteroBackend::new(node, ctl), 1.0);
        engine.set_initial_pcap(360.0);
        let mut policy = StaticCap { pcap: 360.0 };
        for i in 1..=50 {
            engine.tick(i as f64, &mut policy);
        }
        engine.record()
    };
    let a = run(1);
    let b = run(1);
    let c = run(2);
    assert_eq!(a.devices.len(), 2);
    assert_eq!(a.to_json().dump(), b.to_json().dump());
    assert_ne!(a.to_json().dump(), c.to_json().dump());
}
