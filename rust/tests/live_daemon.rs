//! Integration tests of the live path: NRM daemon + transport + workload
//! threads on the wall clock, and the PJRT runtime when artifacts exist.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use powerctl::control::baseline::{Policy, Uncontrolled};
use powerctl::coordinator::nrm::{NrmDaemon, SimBackend};
use powerctl::coordinator::transport::{BeatSender, InProc, UnixSocket};
use powerctl::experiments::{fig6, identify, Ctx, Scale};
use powerctl::sim::cluster::{Cluster, ClusterId};
use powerctl::sim::clock::WallClock;
use powerctl::sim::node::NodeSim;

#[cfg(feature = "pjrt")]
fn artifacts_available() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

/// Fast wall-clock daemon loop (50 ms period) fed by a thread that paces
/// beats to the backend's published rate — the live architecture without
/// PJRT, so it runs everywhere in < 2 s.
#[test]
fn daemon_with_threaded_beat_source_converges() {
    let ctx = Ctx::new(std::env::temp_dir().join("powerctl-it-live1"), 3, Scale::Fast);
    let ident = identify(&ctx, ClusterId::Gros);
    let (policy, sp) = fig6::make_pi(&ident, 0.15);

    // Time acceleration: daemon period 50 ms, node stepped at real dt — the
    // sim plant runs 20× faster than the paper's 1 s period, which only
    // compresses the transient.
    let backend = SimBackend::new(NodeSim::new(Cluster::get(ClusterId::Gros), 3));
    let rate = backend.rate_handle();
    let (tx, rx) = InProc::pair();
    let mut daemon = NrmDaemon::new(
        rx,
        Box::new(backend),
        Box::new(policy) as Box<dyn Policy>,
        0.05,
        sp,
        0.15,
    );

    let stop = Arc::new(AtomicBool::new(false));
    let stop_wl = stop.clone();
    let producer = std::thread::spawn(move || {
        let mut carry = 0.0f64;
        while !stop_wl.load(Ordering::Relaxed) {
            let r = f64::from_bits(rate.load(Ordering::Relaxed));
            let r = if r > 1.0 { r } else { 25.0 };
            // Emit ~r beats/s of *sim* time; the daemon steps the node by
            // wall dt, so sim time ≈ wall time here.
            carry += r * 0.005;
            while carry >= 1.0 {
                let _ = tx.send(1, 1);
                carry -= 1.0;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    });

    let mut clock = WallClock::new();
    let rec = daemon.run(&mut clock, &stop, None, 1.5);
    stop.store(true, Ordering::Relaxed);
    producer.join().unwrap();

    assert!(rec.pcap.len() > 10, "too few control periods");
    // The cap must have responded (moved off the initial rail).
    let caps = &rec.pcap.values;
    assert!(
        caps.iter().any(|&c| c < 119.0),
        "controller never actuated: {caps:?}"
    );
}

#[test]
fn unix_socket_end_to_end_under_load() {
    // 4 producer threads × 2,000 datagrams through the real socket.
    let path = std::env::temp_dir().join(format!("powerctl-it-uds-{}.sock", std::process::id()));
    let receiver = UnixSocket::bind(&path).unwrap();
    let mut daemon = NrmDaemon::new(
        receiver,
        Box::new(SimBackend::new(NodeSim::new(
            Cluster::get(ClusterId::Gros),
            4,
        ))),
        Box::new(Uncontrolled { pcap_max: 120.0 }),
        0.05,
        f64::NAN,
        f64::NAN,
    );
    let total = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for app in 0..4u32 {
        let path = path.clone();
        let total = total.clone();
        handles.push(std::thread::spawn(move || {
            let tx = UnixSocket::connect(&path).unwrap();
            for _ in 0..2000 {
                if tx.send(app, 1).is_ok() {
                    total.fetch_add(1, Ordering::Relaxed);
                }
                // Datagram sockets drop under overload; tiny yield keeps
                // the kernel buffer drained by the daemon side.
                std::hint::spin_loop();
            }
        }));
    }
    let mut now = 0.0;
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while std::time::Instant::now() < deadline {
        now += 0.05;
        let s = daemon.tick(now);
        if handles.iter().all(|h| h.is_finished()) {
            // One final drain tick.
            let s2 = daemon.tick(now + 0.05);
            let sent = total.load(Ordering::Relaxed);
            let received = s2.beats_total.max(s.beats_total);
            // UDS datagrams on the same host are reliable unless the
            // receive buffer overflows; the drain loop keeps up, so expect
            // the vast majority delivered.
            assert!(
                received >= sent * 9 / 10,
                "received {received} of {sent} beats"
            );
            for h in handles {
                h.join().unwrap();
            }
            return;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    panic!("producers did not finish in time");
}

// Needs the real PJRT runtime: the stub's `Runtime::new` errors even when
// artifacts exist, so this test only makes sense with the feature on.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_live_workload_through_daemon() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use powerctl::workload::{run_live, LiveConfig};
    let ctx = Ctx::new(std::env::temp_dir().join("powerctl-it-live2"), 5, Scale::Fast);
    let ident = identify(&ctx, ClusterId::Gros);
    let (policy, sp) = fig6::make_pi(&ident, 0.15);
    let backend = SimBackend::new(NodeSim::new(Cluster::get(ClusterId::Gros), 5));
    let rate = backend.rate_handle();
    let (tx, rx) = InProc::pair();
    let mut daemon = NrmDaemon::new(
        rx,
        Box::new(backend),
        Box::new(policy) as Box<dyn Policy>,
        0.25,
        sp,
        0.15,
    );
    let stop = Arc::new(AtomicBool::new(false));
    let stop_wl = stop.clone();
    let wl = std::thread::spawn(move || {
        let result = (|| {
            let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            let rt = powerctl::runtime::Runtime::new(dir)?;
            let ex = powerctl::runtime::StreamExecutor::new(rt, 5, true)?;
            run_live(
                ex,
                &tx,
                rate,
                &stop_wl,
                &LiveConfig {
                    app_id: 1,
                    iterations: 12,
                    initial_rate: 50.0,
                    check_digest: true,
                },
            )
        })();
        stop_wl.store(true, Ordering::Relaxed);
        result
    });
    let mut clock = WallClock::new();
    let rec = daemon.run(&mut clock, &stop, Some(12), 60.0);
    stop.store(true, Ordering::Relaxed);
    let outcome = wl.join().unwrap().expect("workload failed");
    assert_eq!(outcome.iterations, 12);
    assert!(rec.beats >= 12, "daemon saw {} beats", rec.beats);
}

#[test]
fn beat_sender_trait_objects_interchangeable() {
    // The workload is generic over the transport: both implementations
    // must satisfy the same contract.
    let (tx, _rx) = InProc::pair();
    let senders: Vec<Box<dyn BeatSender>> = vec![Box::new(tx)];
    for s in &senders {
        s.send(1, 1).unwrap();
    }
}
