//! Scheduler determinism: the resident-shard executor's *scheduling*
//! freedom — worker count, cost-weighted partition, measured-load
//! rebalancing migrations — must never reach the bytes.
//!
//! Property (satellite of the resident-executor PR): on random mixed
//! CPU / CPU+GPU fleets under all three budget policies, with rebalancing
//! enabled (the default), `RunRecord::to_json` is byte-identical
//! across worker counts {1, 2, all-cores} and across repeated runs.
//! Rebalancing decisions feed on *measured wall times* — OS scheduling
//! noise decides when migrations fire — so repeated runs exercise
//! different migration histories over identical byte streams; the
//! property holding is exactly the claim that migrations are lossless.

use powerctl::control::budget::{BudgetPolicy, GreedyRepack, SlackProportional, UniformBudget};
use powerctl::control::node_budget::DeviceSplitSpec;
use powerctl::fleet::node::noise_free_model;
use powerctl::fleet::{
    run_fleet, FleetConfig, FleetOutcome, NodeHardware, NodePolicySpec, NodeSpec,
};
use powerctl::sim::cluster::{Cluster, ClusterId};
use powerctl::util::rng::Pcg64;

fn record_bytes(out: &FleetOutcome) -> String {
    out.records
        .iter()
        .map(|r| r.to_json().dump())
        .collect::<Vec<_>>()
        .join("\n")
}

fn strategy(name: &str) -> Box<dyn BudgetPolicy> {
    match name {
        "uniform" => Box::new(UniformBudget),
        "slack-proportional" => Box::new(SlackProportional::default()),
        "greedy-repack" => Box::new(GreedyRepack::default()),
        other => panic!("unknown strategy {other}"),
    }
}

/// Draw a random mixed fleet (single-CPU and CPU+GPU nodes over the three
/// clusters) and a config whose tight-ish budget makes reallocation epochs
/// move watts.
fn random_fleet(rng: &mut Pcg64) -> (Vec<NodeSpec>, FleetConfig) {
    let clusters = [ClusterId::Gros, ClusterId::Dahu, ClusterId::Yeti];
    let n = 3 + rng.below(6) as usize;
    let mut budget = 0.0;
    let specs: Vec<NodeSpec> = (0..n)
        .map(|_| {
            let id = *rng.choose(&clusters);
            let cluster = Cluster::get(id);
            if rng.f64() < 0.4 {
                budget += 0.7 * (cluster.pcap_max + 400.0);
                NodeSpec {
                    cluster: id,
                    model: noise_free_model(id),
                    policy: NodePolicySpec::Static,
                    hardware: NodeHardware::cpu_gpu(
                        &cluster,
                        *rng.choose(&[
                            DeviceSplitSpec::Even,
                            DeviceSplitSpec::SlackShift,
                            DeviceSplitSpec::GreedyRepack,
                        ]),
                        rng.uniform(0.05, 0.3),
                    ),
                }
            } else {
                budget += rng.uniform(0.7, 0.95) * cluster.pcap_max;
                NodeSpec {
                    cluster: id,
                    model: noise_free_model(id),
                    policy: NodePolicySpec::Pi {
                        epsilon: rng.uniform(0.0, 0.3),
                    },
                    hardware: NodeHardware::SingleCpu,
                }
            }
        })
        .collect();
    let cfg = FleetConfig {
        budget,
        period: 1.0,
        realloc_every: 1 + rng.below(5),
        total_beats: 200 + rng.below(300),
        max_time: 90.0,
        seed: rng.next_u64(),
        threads: None,
    };
    (specs, cfg)
}

#[test]
fn worker_count_and_rebalancing_never_change_bytes() {
    let mut rng = Pcg64::seeded(0x5EED5);
    for case in 0..3 {
        let (specs, base) = random_fleet(&mut rng);
        for name in ["uniform", "slack-proportional", "greedy-repack"] {
            let run = |threads: Option<usize>| {
                let cfg = FleetConfig {
                    threads,
                    ..base.clone()
                };
                run_fleet(&specs, strategy(name).as_mut(), &cfg)
            };
            let all_cores = run(None);
            let one = run(Some(1));
            let two = run(Some(2));
            let reference = record_bytes(&all_cores);
            assert_eq!(
                reference,
                record_bytes(&one),
                "case {case} strategy {name}: all-cores != 1 worker ({} nodes, seed {})",
                specs.len(),
                base.seed
            );
            assert_eq!(
                reference,
                record_bytes(&two),
                "case {case} strategy {name}: all-cores != 2 workers"
            );
            assert_eq!(
                all_cores.limits_trace, one.limits_trace,
                "case {case} strategy {name}: ceiling traces diverge (1 worker)"
            );
            assert_eq!(
                all_cores.limits_trace, two.limits_trace,
                "case {case} strategy {name}: ceiling traces diverge (2 workers)"
            );
        }
    }
}

#[test]
fn repeated_runs_with_rebalancing_are_byte_identical() {
    // Rebalancing migrations fire off measured wall times, so two runs
    // of the same fleet can migrate at different periods — the bytes
    // must not notice. Repeat a few times to widen the window for a
    // divergent migration history.
    let mut rng = Pcg64::seeded(0xD15EA5E);
    let (specs, cfg) = random_fleet(&mut rng);
    let reference = record_bytes(&run_fleet(
        &specs,
        strategy("slack-proportional").as_mut(),
        &cfg,
    ));
    for rep in 0..3 {
        let again = record_bytes(&run_fleet(
            &specs,
            strategy("slack-proportional").as_mut(),
            &cfg,
        ));
        assert_eq!(reference, again, "rep {rep}: records drifted across runs");
    }
}
