//! Coordinator-tree equivalence: the depth-1 tree **is** the flat budget
//! path — byte-identical `RunRecord` JSON and identical `limits_trace`
//! for every budget policy on every stepping path — and any tree shape
//! is deterministic: same bytes across worker counts {1, 2, all} and
//! across repeated runs (the executor's parallel sub-tree passes may
//! only change wall time, never bytes).
//!
//! This is the depth-equivalence contract that lets the fleet keep one
//! drive loop: the flat path is the degenerate tree, not a parallel
//! implementation.

use powerctl::control::tree::{BudgetPolicySpec, CoordinatorTree, TreeSpec};
use powerctl::fleet::node::noise_free_model;
use powerctl::fleet::{
    run_fleet_tree_with_path, run_fleet_with_path, FleetConfig, FleetOutcome, NodeHardware,
    NodePolicySpec, NodeSpec, SimPath,
};
use powerctl::sim::cluster::ClusterId;
use powerctl::util::rng::Pcg64;

/// 32 nodes over two clusters (alternating gros/dahu), PI at ε = 0.15 —
/// the same fleet the executor equivalence suite pins.
fn specs() -> Vec<NodeSpec> {
    let order = [ClusterId::Gros, ClusterId::Dahu];
    let models = [
        noise_free_model(ClusterId::Gros),
        noise_free_model(ClusterId::Dahu),
    ];
    (0..32)
        .map(|i| NodeSpec {
            cluster: order[i % 2],
            model: models[i % 2].clone(),
            policy: NodePolicySpec::Pi { epsilon: 0.15 },
            hardware: NodeHardware::SingleCpu,
        })
        .collect()
}

fn config() -> FleetConfig {
    FleetConfig {
        // Tight budget: reallocation epochs actually move watts, so the
        // identity check covers allocation, not just ticking.
        budget: 32.0 * 85.0,
        period: 1.0,
        realloc_every: 5,
        total_beats: 400,
        max_time: 120.0,
        seed: 7,
        threads: None,
    }
}

/// Serialize every record of an outcome to its canonical JSON bytes.
fn record_bytes(out: &FleetOutcome) -> String {
    out.records
        .iter()
        .map(|r| r.to_json().dump())
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn depth1_tree_is_byte_identical_to_flat_path() {
    // Every budget policy × every stepping path: the flat allocator and
    // the depth-1 tree built from the same policy spec must agree byte
    // for byte, records and ceiling trace both.
    let specs = specs();
    let base = config();
    for policy in BudgetPolicySpec::ALL {
        for path in [SimPath::Batched, SimPath::BatchedScalar, SimPath::Classic] {
            let mut flat = policy.build();
            let flat_out = run_fleet_with_path(&specs, flat.as_mut(), &base, path);

            let mut tree = CoordinatorTree::new(&TreeSpec::flat(policy, specs.len()));
            let tree_out = run_fleet_tree_with_path(&specs, &mut tree, &base, path);

            assert!(
                record_bytes(&flat_out) == record_bytes(&tree_out),
                "{} on {path:?}: depth-1 tree records != flat records",
                policy.name()
            );
            assert_eq!(
                flat_out.limits_trace,
                tree_out.limits_trace,
                "{} on {path:?}: ceiling trace diverged",
                policy.name()
            );
            assert!(
                !flat_out.limits_trace.is_empty(),
                "{} on {path:?}: no epochs ran — the check would be vacuous",
                policy.name()
            );
        }
    }
}

/// A random tree shape over `leaves` nodes: up to `depth` interior
/// levels, uneven arity (2–4 groups per interior, sizes drawn from the
/// RNG), with some groups attached as direct leaves of their parent so
/// paths have uneven length.
fn random_spec(rng: &mut Pcg64, policy: BudgetPolicySpec, depth: usize, leaves: usize) -> TreeSpec {
    if depth <= 1 || leaves < 4 {
        return TreeSpec::flat(policy, leaves);
    }
    let groups = (2 + rng.below(3) as usize).min(leaves);
    let mut sizes = vec![1usize; groups];
    for _ in 0..(leaves - groups) {
        let g = rng.below(groups as u64) as usize;
        sizes[g] += 1;
    }
    let children = sizes
        .iter()
        .map(|&k| {
            if rng.below(3) == 0 {
                TreeSpec::Leaves(k)
            } else {
                random_spec(rng, policy, depth - 1, k)
            }
        })
        .collect();
    TreeSpec::Interior { policy, children }
}

/// A 24-node fleet where roughly a quarter of the leaves are
/// hierarchical CPU+GPU nodes (their inner loop splits the fleet ceiling
/// across devices) and the rest are single-CPU PI nodes.
fn mixed_specs(rng: &mut Pcg64) -> (Vec<NodeSpec>, f64) {
    use powerctl::control::node_budget::DeviceSplitSpec;
    use powerctl::sim::cluster::Cluster;

    let order = [ClusterId::Gros, ClusterId::Dahu, ClusterId::Yeti];
    let mut budget = 0.0;
    let specs = (0..24)
        .map(|i| {
            if rng.below(4) == 0 {
                budget += 360.0;
                let cluster = Cluster::get(ClusterId::Gros);
                NodeSpec {
                    cluster: ClusterId::Gros,
                    model: noise_free_model(ClusterId::Gros),
                    policy: NodePolicySpec::Static,
                    hardware: NodeHardware::cpu_gpu(&cluster, DeviceSplitSpec::SlackShift, 0.15),
                }
            } else {
                budget += 85.0;
                let cluster = order[i % order.len()];
                NodeSpec {
                    cluster,
                    model: noise_free_model(cluster),
                    policy: NodePolicySpec::Pi { epsilon: 0.15 },
                    hardware: NodeHardware::SingleCpu,
                }
            }
        })
        .collect();
    (specs, budget)
}

#[test]
fn random_tree_shapes_are_deterministic_across_worker_counts() {
    // Property: for random shapes (depth 1–4, uneven arity, hetero
    // CPU/GPU leaves mixed in), the run is bit-reproducible on worker
    // pools of 1 (serial allocation), 2 (parallel sub-tree passes) and
    // all cores — and across repeated runs on the same pool.
    let mut rng = Pcg64::seeded(0x7EE5);
    for depth in 1..=4usize {
        let policy = BudgetPolicySpec::ALL[depth % BudgetPolicySpec::ALL.len()];
        let (specs, budget) = mixed_specs(&mut rng);
        let spec = random_spec(&mut rng, policy, depth, specs.len());
        assert_eq!(spec.leaf_count(), specs.len());
        let base = FleetConfig {
            budget,
            period: 1.0,
            realloc_every: 5,
            total_beats: 300,
            max_time: 120.0,
            seed: 13 + depth as u64,
            threads: None,
        };

        let mut outs = Vec::new();
        for threads in [Some(1), Some(2), None, None] {
            let cfg = FleetConfig {
                threads,
                ..base.clone()
            };
            let mut tree = CoordinatorTree::new(&spec);
            outs.push(run_fleet_tree_with_path(&specs, &mut tree, &cfg, SimPath::Batched));
        }
        let reference = record_bytes(&outs[0]);
        for (i, out) in outs.iter().enumerate().skip(1) {
            assert!(
                record_bytes(out) == reference,
                "depth {depth} ({}) variant {i}: records diverged across worker counts",
                policy.name()
            );
            assert_eq!(
                out.limits_trace, outs[0].limits_trace,
                "depth {depth} ({}) variant {i}: ceiling trace diverged",
                policy.name()
            );
        }
        assert!(
            !outs[0].limits_trace.is_empty(),
            "depth {depth}: no epochs ran — the property would be vacuous"
        );
    }
}
