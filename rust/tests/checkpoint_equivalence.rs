//! Checkpoint/restore equivalence contract.
//!
//! Four pins:
//!
//! 1. `kill_at_every_period_resume_is_byte_identical` — a fleet run under
//!    an active fault plan, killed after *every* possible node period and
//!    resumed from the checkpoint written that period, reproduces the
//!    uninterrupted run byte-for-byte: per-node record JSON, the full
//!    ceilings trace, and the summary scalars all match exactly.
//! 2. The same identity holds across the stepping paths (batched-scalar,
//!    classic) and under a depth-3 coordinator tree — the checkpoint
//!    captures semantic state only, so it is portable across execution
//!    strategies.
//! 3. A *real* checkpoint file truncated at any length, or with any bit
//!    flipped, is rejected with a recoverable error — never a panic,
//!    never a silently divergent resume.
//! 4. Resuming under a different configuration (fleet size, budget,
//!    stepping path, allocator shape) is rejected with a descriptive
//!    error before any state is touched.

use std::path::PathBuf;

use powerctl::control::budget::SlackProportional;
use powerctl::control::tree::{BudgetPolicySpec, CoordinatorTree, TreeSpec};
use powerctl::experiments::checkpoint::outcomes_identical;
use powerctl::fleet::node::noise_free_model;
use powerctl::fleet::{
    resume_fleet, resume_fleet_tree, run_fleet_killed, run_fleet_tree_killed,
    run_fleet_tree_with_faults, run_fleet_with_faults, CheckpointSpec, FleetConfig, FleetOutcome,
    NodeHardware, NodePolicySpec, NodeSpec, SimPath,
};
use powerctl::sim::cluster::ClusterId;
use powerctl::sim::faults::{FaultPlan, FaultRegime, NodeSelector};

fn specs(n: usize) -> Vec<NodeSpec> {
    let order = [ClusterId::Gros, ClusterId::Dahu];
    let models = [
        noise_free_model(ClusterId::Gros),
        noise_free_model(ClusterId::Dahu),
    ];
    (0..n)
        .map(|i| NodeSpec {
            cluster: order[i % 2],
            model: models[i % 2].clone(),
            policy: NodePolicySpec::Pi { epsilon: 0.15 },
            hardware: NodeHardware::SingleCpu,
        })
        .collect()
}

fn config(n: usize) -> FleetConfig {
    FleetConfig {
        budget: n as f64 * 85.0,
        period: 1.0,
        realloc_every: 5,
        total_beats: 300,
        max_time: 120.0,
        seed: 7,
        threads: None,
    }
}

/// Live fault plane during every run: one crash-with-restart plus
/// fleetwide sensor dropout, so the checkpoint must carry fault state
/// (armed restarts, event logs, fault RNG streams) to reproduce bytes.
fn plan() -> FaultPlan {
    FaultPlan::seeded(0x5EED)
        .with_rule(
            NodeSelector::Node(2),
            FaultRegime {
                crash_at: Some(20.0),
                restart_after: Some(30.0),
                ..FaultRegime::default()
            },
        )
        .with_rule(
            NodeSelector::All,
            FaultRegime {
                sensor_dropout: 0.10,
                ..FaultRegime::default()
            },
        )
}

fn ckpt_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("powerctl-ckpt-eq-{tag}-{}.bin", std::process::id()))
}

/// Total node periods the uninterrupted run took (the break period
/// included) — kills are only possible strictly before it.
fn final_period(out: &FleetOutcome, n: usize) -> u64 {
    out.node_ticks / n as u64
}

#[test]
fn kill_at_every_period_resume_is_byte_identical() {
    let n = 6;
    let specs = specs(n);
    let cfg = config(n);
    let plan = plan();
    let oracle = run_fleet_with_faults(
        &specs,
        &mut SlackProportional::default(),
        &cfg,
        SimPath::Batched,
        &plan,
    );
    let last = final_period(&oracle, n);
    assert!(last > 20, "run too short ({last} periods) for a meaningful sweep");
    let ckpt = CheckpointSpec {
        every: 1,
        path: ckpt_path("sweep"),
    };
    for kill_at in 1..last {
        let killed = run_fleet_killed(
            &specs,
            &mut SlackProportional::default(),
            &cfg,
            SimPath::Batched,
            &plan,
            &ckpt,
            kill_at,
        )
        .expect("checkpointed drive failed");
        assert!(killed.is_none(), "kill at {kill_at} did not fire");
        let resumed = resume_fleet(
            &specs,
            &mut SlackProportional::default(),
            &cfg,
            SimPath::Batched,
            &plan,
            &ckpt.path,
        )
        .expect("resume failed");
        assert!(
            outcomes_identical(&oracle, &resumed),
            "resume after kill at period {kill_at} diverged from the oracle"
        );
    }
    let _ = std::fs::remove_file(&ckpt.path);
}

#[test]
fn kill_resume_identity_across_paths_and_allocators() {
    let n = 6;
    let specs = specs(n);
    let cfg = config(n);
    let plan = plan();

    // The other two stepping paths, flat allocation.
    for (tag, path) in [
        ("scalar", SimPath::BatchedScalar),
        ("classic", SimPath::Classic),
    ] {
        let oracle =
            run_fleet_with_faults(&specs, &mut SlackProportional::default(), &cfg, path, &plan);
        let last = final_period(&oracle, n);
        assert!(last > 22, "{path:?}: run too short ({last} periods)");
        let ckpt = CheckpointSpec {
            every: 1,
            path: ckpt_path(tag),
        };
        // Mid-epoch, on-epoch, just-after-crash, and late kills.
        for kill_at in [3, 5, 21, last / 2, last - 1] {
            let killed = run_fleet_killed(
                &specs,
                &mut SlackProportional::default(),
                &cfg,
                path,
                &plan,
                &ckpt,
                kill_at,
            )
            .expect("checkpointed drive failed");
            assert!(killed.is_none(), "{path:?}: kill at {kill_at} did not fire");
            let resumed = resume_fleet(
                &specs,
                &mut SlackProportional::default(),
                &cfg,
                path,
                &plan,
                &ckpt.path,
            )
            .expect("resume failed");
            assert!(
                outcomes_identical(&oracle, &resumed),
                "{path:?}: resume after kill at {kill_at} diverged"
            );
        }
        let _ = std::fs::remove_file(&ckpt.path);
    }

    // Depth-3 coordinator tree on the default path. The resumed tree is
    // freshly built: interior allocator state is per-epoch scratch, so
    // only the drive loop's state needs the checkpoint.
    let spec = TreeSpec::balanced(BudgetPolicySpec::SlackProportional, 3, 2, n);
    let mut t1 = CoordinatorTree::new(&spec);
    let oracle = run_fleet_tree_with_faults(&specs, &mut t1, &cfg, SimPath::Batched, &plan);
    let last = final_period(&oracle, n);
    assert!(last > 22, "tree: run too short ({last} periods)");
    let ckpt = CheckpointSpec {
        every: 1,
        path: ckpt_path("tree"),
    };
    for kill_at in [3, 5, 21, last / 2, last - 1] {
        let mut t2 = CoordinatorTree::new(&spec);
        let killed =
            run_fleet_tree_killed(&specs, &mut t2, &cfg, SimPath::Batched, &plan, &ckpt, kill_at)
                .expect("checkpointed tree drive failed");
        assert!(killed.is_none(), "tree: kill at {kill_at} did not fire");
        let mut t3 = CoordinatorTree::new(&spec);
        let resumed =
            resume_fleet_tree(&specs, &mut t3, &cfg, SimPath::Batched, &plan, &ckpt.path)
                .expect("tree resume failed");
        assert!(
            outcomes_identical(&oracle, &resumed),
            "tree: resume after kill at {kill_at} diverged"
        );
    }
    let _ = std::fs::remove_file(&ckpt.path);
}

/// Produce one real checkpoint file and return its bytes.
fn real_checkpoint(tag: &str) -> (Vec<NodeSpec>, FleetConfig, FaultPlan, PathBuf, Vec<u8>) {
    let n = 6;
    let specs = specs(n);
    let cfg = config(n);
    let plan = plan();
    let ckpt = CheckpointSpec {
        every: 1,
        path: ckpt_path(tag),
    };
    let killed = run_fleet_killed(
        &specs,
        &mut SlackProportional::default(),
        &cfg,
        SimPath::Batched,
        &plan,
        &ckpt,
        7,
    )
    .expect("checkpointed drive failed");
    assert!(killed.is_none());
    let bytes = std::fs::read(&ckpt.path).expect("checkpoint file missing");
    (specs, cfg, plan, ckpt.path, bytes)
}

#[test]
fn truncated_or_corrupted_checkpoint_is_rejected_not_panic() {
    let (specs, cfg, plan, path, bytes) = real_checkpoint("corrupt");
    assert!(bytes.len() > 64, "checkpoint suspiciously small");
    let resume = |p: &PathBuf| {
        resume_fleet(
            &specs,
            &mut SlackProportional::default(),
            &cfg,
            SimPath::Batched,
            &plan,
            p,
        )
    };
    // Sanity: the pristine file resumes fine.
    assert!(resume(&path).is_ok(), "pristine checkpoint failed to resume");

    // Truncation at a spread of lengths, the empty file and off-by-one
    // included: always a recoverable error.
    let cut = path.with_extension("cut");
    for len in [0, 1, 7, 8, 12, bytes.len() / 3, bytes.len() / 2, bytes.len() - 1] {
        std::fs::write(&cut, &bytes[..len]).unwrap();
        assert!(
            resume(&cut).is_err(),
            "truncation to {len} of {} bytes was not rejected",
            bytes.len()
        );
    }

    // A single bit flipped anywhere: the section and file CRCs catch it.
    let flip = path.with_extension("flip");
    let stride = (bytes.len() / 97).max(1);
    for off in (0..bytes.len()).step_by(stride) {
        let mut bad = bytes.clone();
        bad[off] ^= 0x10;
        std::fs::write(&flip, &bad).unwrap();
        assert!(resume(&flip).is_err(), "bit flip at byte {off} was not rejected");
    }

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&cut);
    let _ = std::fs::remove_file(&flip);
}

#[test]
fn resume_under_mismatched_config_is_rejected() {
    let (specs, cfg, plan, path, _) = real_checkpoint("mismatch");

    // Wrong fleet size.
    let small = &specs[..4];
    let mut small_cfg = cfg.clone();
    small_cfg.budget = 4.0 * 85.0;
    let e = resume_fleet(
        small,
        &mut SlackProportional::default(),
        &small_cfg,
        SimPath::Batched,
        &plan,
        &path,
    )
    .unwrap_err();
    assert!(e.to_string().contains("nodes"), "{e}");

    // Wrong budget.
    let mut bad_cfg = cfg.clone();
    bad_cfg.budget += 1.0;
    let e = resume_fleet(
        &specs,
        &mut SlackProportional::default(),
        &bad_cfg,
        SimPath::Batched,
        &plan,
        &path,
    )
    .unwrap_err();
    assert!(e.to_string().contains("budget"), "{e}");

    // Wrong stepping path.
    let e = resume_fleet(
        &specs,
        &mut SlackProportional::default(),
        &cfg,
        SimPath::Classic,
        &plan,
        &path,
    )
    .unwrap_err();
    assert!(e.to_string().contains("path"), "{e}");

    // Wrong allocator shape: the checkpoint came from a flat run.
    let spec = TreeSpec::balanced(BudgetPolicySpec::SlackProportional, 3, 2, specs.len());
    let mut tree = CoordinatorTree::new(&spec);
    let e = resume_fleet_tree(&specs, &mut tree, &cfg, SimPath::Batched, &plan, &path)
        .unwrap_err();
    assert!(e.to_string().contains("allocator"), "{e}");

    let _ = std::fs::remove_file(&path);
}
