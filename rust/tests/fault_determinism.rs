//! Fault-plane determinism contract.
//!
//! Three pins:
//!
//! 1. `faults_empty_plan_identical` — an empty (or all-inert, "exhausted")
//!    [`FaultPlan`] is **byte-identical** to the fault-free path on all
//!    three `SimPath`s under every budget policy: the fault plane costs
//!    nothing — not one RNG draw, not one JSON key — until a rule matches.
//! 2. A seeded 64-node campaign under 10% node-crash + sensor-dropout is
//!    byte-identical to itself on replay, completes without panic, and
//!    shows every failed node's watts reclaimed by the budget layer within
//!    one reallocation epoch.
//! 3. Panic isolation: one node engine panicking mid-run quarantines that
//!    node only — the campaign completes, the node is marked failed, and
//!    (under frozen ceilings) every other node's record is byte-identical
//!    to a run where the panic never happened.

use powerctl::control::budget::{
    BudgetPolicy, FrozenLimits, GreedyRepack, SlackProportional, UniformBudget,
};
use powerctl::fleet::node::noise_free_model;
use powerctl::fleet::{
    run_fleet_with_faults, run_fleet_with_path, FleetConfig, FleetOutcome, NodeHardware,
    NodePolicySpec, NodeSpec, SimPath,
};
use powerctl::sim::cluster::ClusterId;
use powerctl::sim::faults::{FaultEventKind, FaultPlan, FaultRegime, NodeSelector};

fn specs(n: usize) -> Vec<NodeSpec> {
    let order = [ClusterId::Gros, ClusterId::Dahu];
    let models = [
        noise_free_model(ClusterId::Gros),
        noise_free_model(ClusterId::Dahu),
    ];
    (0..n)
        .map(|i| NodeSpec {
            cluster: order[i % 2],
            model: models[i % 2].clone(),
            policy: NodePolicySpec::Pi { epsilon: 0.15 },
            hardware: NodeHardware::SingleCpu,
        })
        .collect()
}

fn config(n: usize) -> FleetConfig {
    FleetConfig {
        budget: n as f64 * 85.0,
        period: 1.0,
        realloc_every: 5,
        total_beats: 300,
        max_time: 120.0,
        seed: 7,
        threads: None,
    }
}

fn strategies() -> Vec<(&'static str, Box<dyn BudgetPolicy>)> {
    vec![
        ("frozen", Box::new(FrozenLimits) as Box<dyn BudgetPolicy>),
        ("uniform", Box::new(UniformBudget)),
        ("slack-proportional", Box::new(SlackProportional::default())),
        ("greedy-repack", Box::new(GreedyRepack::default())),
    ]
}

fn record_bytes(out: &FleetOutcome) -> String {
    out.records
        .iter()
        .map(|r| r.to_json().dump())
        .collect::<Vec<_>>()
        .join("\n")
}

/// The CI grep gate anchors on this test name (see `.github/workflows/
/// ci.yml`): empty and exhausted fault plans are byte-free no-ops on
/// every stepping path under every budget policy.
#[test]
fn faults_empty_plan_identical() {
    let specs = specs(12);
    let cfg = config(12);
    // "Exhausted": rules present but all inert — no channel can ever fire,
    // so `node_faults` installs nothing.
    let exhausted = FaultPlan::seeded(99).with_rule(NodeSelector::All, FaultRegime::default());
    for path in [SimPath::Batched, SimPath::BatchedScalar, SimPath::Classic] {
        for (name, _) in strategies() {
            let mut mk = |n: &str| -> Box<dyn BudgetPolicy> {
                strategies().into_iter().find(|(s, _)| *s == n).unwrap().1
            };
            let clean = run_fleet_with_path(&specs, mk(name).as_mut(), &cfg, path);
            let empty =
                run_fleet_with_faults(&specs, mk(name).as_mut(), &cfg, path, &FaultPlan::default());
            let inert = run_fleet_with_faults(&specs, mk(name).as_mut(), &cfg, path, &exhausted);
            let a = record_bytes(&clean);
            assert!(
                a == record_bytes(&empty),
                "{path:?}/{name}: empty plan changed bytes"
            );
            assert!(
                a == record_bytes(&inert),
                "{path:?}/{name}: all-inert plan changed bytes"
            );
            assert_eq!(clean.limits_trace, empty.limits_trace, "{path:?}/{name}");
            assert_eq!(clean.limits_trace, inert.limits_trace, "{path:?}/{name}");
            // No fault key may appear in any record's JSON.
            assert!(
                !a.contains("\"faults\""),
                "{path:?}/{name}: clean records grew a faults key"
            );
        }
    }
}

/// Acceptance scenario: 64 nodes, ~10% crashed permanently plus fleetwide
/// 10% sensor dropout. Replay is byte-identical; the run completes without
/// panicking; every crashed node's watts are reclaimed (parked at the
/// 40 W floor) by the first reallocation epoch after its crash.
#[test]
fn seeded_64_node_crash_dropout_campaign_is_replayable() {
    let n = 64;
    let specs = specs(n);
    let cfg = config(n);
    let crash_t = 23.0;
    let plan = FaultPlan::seeded(0xC4A5)
        .with_rule(
            // Nodes 3, 13, 23, ... — 7 of 64 ≈ 10% — die for good.
            NodeSelector::EveryKth { k: 10, offset: 3 },
            FaultRegime {
                crash_at: Some(crash_t),
                sensor_dropout: 0.10,
                ..FaultRegime::default()
            },
        )
        .with_rule(
            NodeSelector::All,
            FaultRegime {
                sensor_dropout: 0.10,
                ..FaultRegime::default()
            },
        );
    let run = || {
        let mut strat = SlackProportional::default();
        run_fleet_with_faults(&specs, &mut strat, &cfg, SimPath::Batched, &plan)
    };
    let a = run();
    let b = run();
    assert_eq!(record_bytes(&a), record_bytes(&b), "replay diverged");
    assert_eq!(a.limits_trace, b.limits_trace, "ceiling traces diverged");

    let crashed: Vec<usize> = (0..n).filter(|i| i % 10 == 3).collect();
    assert_eq!(crashed.len(), 7);
    // Reclamation within one epoch: the first epoch at/after the crash
    // parks every crashed node at the floor.
    let (t, limits) = a
        .limits_trace
        .iter()
        .find(|(t, _)| *t >= crash_t)
        .expect("no epoch after the crash");
    for &i in &crashed {
        assert_eq!(
            limits[i], 40.0,
            "node {i} not parked at the floor at epoch t={t}"
        );
        assert!(
            !a.records[i].completed,
            "permanently crashed node {i} reported complete"
        );
        assert!(
            a.records[i]
                .faults
                .iter()
                .any(|e| e.kind == FaultEventKind::Crash),
            "node {i} crash not logged"
        );
    }
    // Survivors all completed — under dropout, with reclaimed watts.
    for (i, r) in a.records.iter().enumerate() {
        if !crashed.contains(&i) {
            assert!(r.completed, "survivor {i} did not complete");
        }
    }
}

/// One engine panics mid-run; under frozen ceilings every other node's
/// record is byte-identical to the panic-free run, and the campaign
/// still completes.
#[test]
fn panic_isolation_leaves_survivor_bytes_untouched() {
    let n = 12;
    let doomed = 5usize;
    let specs = specs(n);
    let cfg = config(n);
    let plan = FaultPlan::seeded(0xBAD).with_rule(
        NodeSelector::Node(doomed as u32),
        FaultRegime {
            panic_at: Some(15.0),
            ..FaultRegime::default()
        },
    );
    let clean = run_fleet_with_path(&specs, &mut FrozenLimits, &cfg, SimPath::Batched);
    let faulty = run_fleet_with_faults(&specs, &mut FrozenLimits, &cfg, SimPath::Batched, &plan);

    assert!(
        faulty.records[doomed]
            .faults
            .iter()
            .any(|e| e.kind == FaultEventKind::Panic),
        "panic not logged on the doomed node"
    );
    assert!(!faulty.records[doomed].completed);
    for i in (0..n).filter(|&i| i != doomed) {
        assert_eq!(
            clean.records[i].to_json().dump(),
            faulty.records[i].to_json().dump(),
            "node {i}'s bytes perturbed by node {doomed}'s panic"
        );
        assert!(faulty.records[i].completed, "survivor {i} did not complete");
    }
}
