//! Fault-plane determinism contract.
//!
//! Three pins:
//!
//! 1. `faults_empty_plan_identical` — an empty (or all-inert, "exhausted")
//!    [`FaultPlan`] is **byte-identical** to the fault-free path on all
//!    three `SimPath`s under every budget policy: the fault plane costs
//!    nothing — not one RNG draw, not one JSON key — until a rule matches.
//! 2. A seeded 64-node campaign under 10% node-crash + sensor-dropout is
//!    byte-identical to itself on replay, completes without panic, and
//!    shows every failed node's watts reclaimed by the budget layer within
//!    one reallocation epoch.
//! 3. Panic isolation: one node engine panicking mid-run quarantines that
//!    node only — the campaign completes, the node is marked failed, and
//!    (under frozen ceilings) every other node's record is byte-identical
//!    to a run where the panic never happened.
//! 4. Tree composition (PR 8): the fault plane composes with the
//!    hierarchical coordinator tree unchanged — a crashed leaf's watts
//!    reclaim within one epoch at *every* level of a depth-3 tree,
//!    survivors' bytes stay untouched under frozen ceilings, and a
//!    crash/restart + dropout plan replays byte-identically, grant trace
//!    included.

use powerctl::control::budget::{
    BudgetPolicy, FrozenLimits, GreedyRepack, SlackProportional, UniformBudget,
};
use powerctl::control::tree::{BudgetPolicySpec, CoordinatorTree, TreeSpec};
use powerctl::fleet::node::noise_free_model;
use powerctl::fleet::{
    run_fleet_tree_with_faults, run_fleet_with_faults, run_fleet_with_path, FleetConfig,
    FleetOutcome, NodeHardware, NodePolicySpec, NodeSpec, SimPath,
};
use powerctl::sim::cluster::ClusterId;
use powerctl::sim::faults::{FaultEventKind, FaultPlan, FaultRegime, NodeSelector};

fn specs(n: usize) -> Vec<NodeSpec> {
    let order = [ClusterId::Gros, ClusterId::Dahu];
    let models = [
        noise_free_model(ClusterId::Gros),
        noise_free_model(ClusterId::Dahu),
    ];
    (0..n)
        .map(|i| NodeSpec {
            cluster: order[i % 2],
            model: models[i % 2].clone(),
            policy: NodePolicySpec::Pi { epsilon: 0.15 },
            hardware: NodeHardware::SingleCpu,
        })
        .collect()
}

fn config(n: usize) -> FleetConfig {
    FleetConfig {
        budget: n as f64 * 85.0,
        period: 1.0,
        realloc_every: 5,
        total_beats: 300,
        max_time: 120.0,
        seed: 7,
        threads: None,
    }
}

fn strategies() -> Vec<(&'static str, Box<dyn BudgetPolicy>)> {
    vec![
        ("frozen", Box::new(FrozenLimits) as Box<dyn BudgetPolicy>),
        ("uniform", Box::new(UniformBudget)),
        ("slack-proportional", Box::new(SlackProportional::default())),
        ("greedy-repack", Box::new(GreedyRepack::default())),
    ]
}

fn record_bytes(out: &FleetOutcome) -> String {
    out.records
        .iter()
        .map(|r| r.to_json().dump())
        .collect::<Vec<_>>()
        .join("\n")
}

/// The CI grep gate anchors on this test name (see `.github/workflows/
/// ci.yml`): empty and exhausted fault plans are byte-free no-ops on
/// every stepping path under every budget policy.
#[test]
fn faults_empty_plan_identical() {
    let specs = specs(12);
    let cfg = config(12);
    // "Exhausted": rules present but all inert — no channel can ever fire,
    // so `node_faults` installs nothing.
    let exhausted = FaultPlan::seeded(99).with_rule(NodeSelector::All, FaultRegime::default());
    for path in [SimPath::Batched, SimPath::BatchedScalar, SimPath::Classic] {
        for (name, _) in strategies() {
            let mut mk = |n: &str| -> Box<dyn BudgetPolicy> {
                strategies().into_iter().find(|(s, _)| *s == n).unwrap().1
            };
            let clean = run_fleet_with_path(&specs, mk(name).as_mut(), &cfg, path);
            let empty =
                run_fleet_with_faults(&specs, mk(name).as_mut(), &cfg, path, &FaultPlan::default());
            let inert = run_fleet_with_faults(&specs, mk(name).as_mut(), &cfg, path, &exhausted);
            let a = record_bytes(&clean);
            assert!(
                a == record_bytes(&empty),
                "{path:?}/{name}: empty plan changed bytes"
            );
            assert!(
                a == record_bytes(&inert),
                "{path:?}/{name}: all-inert plan changed bytes"
            );
            assert_eq!(clean.limits_trace, empty.limits_trace, "{path:?}/{name}");
            assert_eq!(clean.limits_trace, inert.limits_trace, "{path:?}/{name}");
            // No fault key may appear in any record's JSON.
            assert!(
                !a.contains("\"faults\""),
                "{path:?}/{name}: clean records grew a faults key"
            );
        }
    }
}

/// Acceptance scenario: 64 nodes, ~10% crashed permanently plus fleetwide
/// 10% sensor dropout. Replay is byte-identical; the run completes without
/// panicking; every crashed node's watts are reclaimed (parked at the
/// 40 W floor) by the first reallocation epoch after its crash.
#[test]
fn seeded_64_node_crash_dropout_campaign_is_replayable() {
    let n = 64;
    let specs = specs(n);
    let cfg = config(n);
    let crash_t = 23.0;
    let plan = FaultPlan::seeded(0xC4A5)
        .with_rule(
            // Nodes 3, 13, 23, ... — 7 of 64 ≈ 10% — die for good.
            NodeSelector::EveryKth { k: 10, offset: 3 },
            FaultRegime {
                crash_at: Some(crash_t),
                sensor_dropout: 0.10,
                ..FaultRegime::default()
            },
        )
        .with_rule(
            NodeSelector::All,
            FaultRegime {
                sensor_dropout: 0.10,
                ..FaultRegime::default()
            },
        );
    let run = || {
        let mut strat = SlackProportional::default();
        run_fleet_with_faults(&specs, &mut strat, &cfg, SimPath::Batched, &plan)
    };
    let a = run();
    let b = run();
    assert_eq!(record_bytes(&a), record_bytes(&b), "replay diverged");
    assert_eq!(a.limits_trace, b.limits_trace, "ceiling traces diverged");

    let crashed: Vec<usize> = (0..n).filter(|i| i % 10 == 3).collect();
    assert_eq!(crashed.len(), 7);
    // Reclamation within one epoch: the first epoch at/after the crash
    // parks every crashed node at the floor.
    let (t, limits) = a
        .limits_trace
        .iter()
        .find(|(t, _)| *t >= crash_t)
        .expect("no epoch after the crash");
    for &i in &crashed {
        assert_eq!(
            limits[i], 40.0,
            "node {i} not parked at the floor at epoch t={t}"
        );
        assert!(
            !a.records[i].completed,
            "permanently crashed node {i} reported complete"
        );
        assert!(
            a.records[i]
                .faults
                .iter()
                .any(|e| e.kind == FaultEventKind::Crash),
            "node {i} crash not logged"
        );
    }
    // Survivors all completed — under dropout, with reclaimed watts.
    for (i, r) in a.records.iter().enumerate() {
        if !crashed.contains(&i) {
            assert!(r.completed, "survivor {i} did not complete");
        }
    }
}

/// One engine panics mid-run; under frozen ceilings every other node's
/// record is byte-identical to the panic-free run, and the campaign
/// still completes.
#[test]
fn panic_isolation_leaves_survivor_bytes_untouched() {
    let n = 12;
    let doomed = 5usize;
    let specs = specs(n);
    let cfg = config(n);
    let plan = FaultPlan::seeded(0xBAD).with_rule(
        NodeSelector::Node(doomed as u32),
        FaultRegime {
            panic_at: Some(15.0),
            ..FaultRegime::default()
        },
    );
    let clean = run_fleet_with_path(&specs, &mut FrozenLimits, &cfg, SimPath::Batched);
    let faulty = run_fleet_with_faults(&specs, &mut FrozenLimits, &cfg, SimPath::Batched, &plan);

    assert!(
        faulty.records[doomed]
            .faults
            .iter()
            .any(|e| e.kind == FaultEventKind::Panic),
        "panic not logged on the doomed node"
    );
    assert!(!faulty.records[doomed].completed);
    for i in (0..n).filter(|&i| i != doomed) {
        assert_eq!(
            clean.records[i].to_json().dump(),
            faulty.records[i].to_json().dump(),
            "node {i}'s bytes perturbed by node {doomed}'s panic"
        );
        assert!(faulty.records[i].completed, "survivor {i} did not complete");
    }
}

/// A crashed leaf under a depth-3 coordinator tree: the first epoch after
/// the crash parks it at the floor in `limits_trace` AND the grant along
/// the whole root→leaf path drops at every level — the reclaimed watts
/// bubble up through all three allocators in the *same* epoch.
#[test]
fn tree_reclaims_crashed_watts_at_every_level_within_one_epoch() {
    let n = 8;
    let crashed = 5usize;
    let crash_t = 18.0;
    let specs = specs(n);
    let cfg = config(n);
    let spec = TreeSpec::balanced(BudgetPolicySpec::SlackProportional, 3, 2, n);
    let mut tree = CoordinatorTree::new(&spec);
    tree.enable_trace();
    let plan = FaultPlan::seeded(31).with_rule(
        NodeSelector::Node(crashed as u32),
        FaultRegime {
            crash_at: Some(crash_t),
            ..FaultRegime::default()
        },
    );
    let out = run_fleet_tree_with_faults(&specs, &mut tree, &cfg, SimPath::Batched, &plan);

    // Leaf-level reclamation, exactly like the flat contract: the first
    // epoch at/after the crash parks the node at the 40 W floor.
    let epoch = out
        .limits_trace
        .iter()
        .position(|(t, _)| *t >= crash_t)
        .expect("no epoch after the crash");
    assert!(epoch >= 1, "need a pre-crash epoch to compare against");
    assert_eq!(
        out.limits_trace[epoch].1[crashed], 40.0,
        "crashed leaf not parked at the floor"
    );
    assert!(
        !out.records[crashed].completed && out.records[crashed]
            .faults
            .iter()
            .any(|e| e.kind == FaultEventKind::Crash),
        "crash not visible on the leaf record"
    );

    // Per-level reclamation: the grant trace records one entry per epoch
    // (same cadence as limits_trace); along the root→leaf path every
    // allocator's grant to the crashed side drops on the crash epoch.
    let trace = tree.trace();
    assert_eq!(trace.len(), out.limits_trace.len(), "trace cadence");
    let path = tree.path_to_leaf(crashed);
    assert_eq!(path.len(), 3, "depth-3 tree has three allocators per path");
    for &(interior, slot) in &path {
        let pre = trace[epoch - 1].grants[interior][slot];
        let post = trace[epoch].grants[interior][slot];
        assert!(
            post < pre - 1.0,
            "interior {interior} slot {slot}: grant {pre:.1} -> {post:.1} did not drop on the crash epoch"
        );
    }
    // Survivors finish with the reclaimed watts.
    for i in (0..n).filter(|&i| i != crashed) {
        assert!(out.records[i].completed, "survivor {i} did not complete");
    }
}

/// Under an all-frozen depth-3 tree, a crash perturbs nobody else: every
/// survivor's record is byte-identical to the crash-free tree run.
#[test]
fn tree_crash_leaves_survivor_bytes_untouched_under_frozen() {
    let n = 8;
    let crashed = 5usize;
    let specs = specs(n);
    let cfg = config(n);
    let spec = TreeSpec::balanced(BudgetPolicySpec::Frozen, 3, 2, n);
    let plan = FaultPlan::seeded(47).with_rule(
        NodeSelector::Node(crashed as u32),
        FaultRegime {
            crash_at: Some(18.0),
            ..FaultRegime::default()
        },
    );
    let mut clean_tree = CoordinatorTree::new(&spec);
    let clean =
        run_fleet_tree_with_faults(&specs, &mut clean_tree, &cfg, SimPath::Batched, &FaultPlan::default());
    let mut faulty_tree = CoordinatorTree::new(&spec);
    let faulty = run_fleet_tree_with_faults(&specs, &mut faulty_tree, &cfg, SimPath::Batched, &plan);

    assert!(!faulty.records[crashed].completed);
    for i in (0..n).filter(|&i| i != crashed) {
        assert_eq!(
            clean.records[i].to_json().dump(),
            faulty.records[i].to_json().dump(),
            "node {i}'s bytes perturbed by node {crashed}'s crash through the tree"
        );
        assert!(faulty.records[i].completed, "survivor {i} did not complete");
    }
}

/// A seeded crash/restart + fleetwide dropout plan under a depth-3 tree
/// replays byte-identically — records, ceiling trace, and the tree's own
/// per-interior grant trace.
#[test]
fn tree_crash_restart_dropout_plan_is_replay_identical() {
    let n = 12;
    let specs = specs(n);
    let cfg = config(n);
    let spec = TreeSpec::balanced(BudgetPolicySpec::SlackProportional, 3, 2, n);
    let plan = FaultPlan::seeded(0x7C4A)
        .with_rule(
            NodeSelector::Node(4),
            FaultRegime {
                crash_at: Some(20.0),
                restart_after: Some(30.0),
                ..FaultRegime::default()
            },
        )
        .with_rule(
            NodeSelector::All,
            FaultRegime {
                sensor_dropout: 0.10,
                ..FaultRegime::default()
            },
        );
    let run = || {
        let mut tree = CoordinatorTree::new(&spec);
        tree.enable_trace();
        let out = run_fleet_tree_with_faults(&specs, &mut tree, &cfg, SimPath::Batched, &plan);
        (out, tree)
    };
    let (a, a_tree) = run();
    let (b, b_tree) = run();
    assert_eq!(record_bytes(&a), record_bytes(&b), "tree replay diverged");
    assert_eq!(a.limits_trace, b.limits_trace, "ceiling traces diverged");
    assert_eq!(a_tree.trace(), b_tree.trace(), "grant traces diverged");
    assert!(
        a.records[4]
            .faults
            .iter()
            .any(|e| e.kind == FaultEventKind::Crash),
        "crash not logged on node 4"
    );
    assert!(
        !a_tree.trace().is_empty(),
        "no grant epochs recorded — the replay check would be vacuous"
    );
}
