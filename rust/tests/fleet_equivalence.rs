//! Executor equivalence: a 32-node, 2-cluster fleet run must produce
//! **byte-identical** `RunRecord` JSON under (a) the resident-shard
//! executor on all cores, (b) a forced single-thread pool, and (c) the
//! legacy one-thread-per-node mpsc protocol — for every reallocation
//! strategy. Path (a)/(b) runs long enough (120 periods) to cross the
//! executor's default rebalance cadence, so the contract covers
//! measured-load migrations of resident state too (see also
//! `tests/scheduler_determinism.rs`).
//!
//! This is the determinism contract of the fleet layer: the execution
//! mechanism may only change wall time, never bytes.

use powerctl::control::budget::{BudgetPolicy, GreedyRepack, SlackProportional, UniformBudget};
use powerctl::fleet::node::noise_free_model;
use powerctl::fleet::{
    run_fleet, run_fleet_threaded, FleetConfig, FleetOutcome, NodeHardware, NodePolicySpec, NodeSpec,
};
use powerctl::sim::cluster::ClusterId;

/// 32 nodes over two clusters (alternating gros/dahu), PI at ε = 0.15.
/// The noise-free fitted models come from the same shared constructor the
/// fleet unit tests use, so every suite fits identical controllers.
fn specs() -> Vec<NodeSpec> {
    let order = [ClusterId::Gros, ClusterId::Dahu];
    let models = [
        noise_free_model(ClusterId::Gros),
        noise_free_model(ClusterId::Dahu),
    ];
    (0..32)
        .map(|i| NodeSpec {
            cluster: order[i % 2],
            model: models[i % 2].clone(),
            policy: NodePolicySpec::Pi { epsilon: 0.15 },
            hardware: NodeHardware::SingleCpu,
        })
        .collect()
}

fn config() -> FleetConfig {
    FleetConfig {
        // Tight budget: reallocation epochs actually move watts, so the
        // equivalence check covers the SetLimit path, not just ticking.
        budget: 32.0 * 85.0,
        period: 1.0,
        realloc_every: 5,
        total_beats: 400,
        max_time: 120.0,
        seed: 7,
        threads: None,
    }
}

fn strategy(name: &str) -> Box<dyn BudgetPolicy> {
    match name {
        "uniform" => Box::new(UniformBudget),
        "slack-proportional" => Box::new(SlackProportional::default()),
        "greedy-repack" => Box::new(GreedyRepack::default()),
        other => panic!("unknown strategy {other}"),
    }
}

/// Serialize every record of an outcome to its canonical JSON bytes.
fn record_bytes(out: &FleetOutcome) -> String {
    out.records
        .iter()
        .map(|r| r.to_json().dump())
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn sharded_single_thread_and_legacy_paths_are_byte_identical() {
    let specs = specs();
    let base = config();
    for name in ["uniform", "slack-proportional", "greedy-repack"] {
        // (a) sharded executor, all cores.
        let sharded = run_fleet(&specs, strategy(name).as_mut(), &base);
        // (b) sharded executor, forced single-thread pool.
        let single_cfg = FleetConfig {
            threads: Some(1),
            ..base.clone()
        };
        let single = run_fleet(&specs, strategy(name).as_mut(), &single_cfg);
        // (c) legacy one-thread-per-node mpsc protocol.
        let legacy = run_fleet_threaded(&specs, strategy(name).as_mut(), &base);

        assert_eq!(sharded.records.len(), 32);
        assert_eq!(sharded.strategy, legacy.strategy);

        let a = record_bytes(&sharded);
        let b = record_bytes(&single);
        let c = record_bytes(&legacy);
        assert!(a == b, "{name}: sharded != single-thread pool records");
        assert!(a == c, "{name}: sharded != legacy per-node-thread records");

        // The budget layer saw identical snapshots too: every epoch's
        // ceilings match across all three paths.
        assert_eq!(sharded.limits_trace, single.limits_trace, "{name}: trace");
        assert_eq!(sharded.limits_trace, legacy.limits_trace, "{name}: trace");
        assert!(
            !sharded.limits_trace.is_empty(),
            "{name}: no reallocation epochs ran — the check would be vacuous"
        );

        // Scalar summaries follow from the records; spot-check anyway.
        assert_eq!(sharded.total_energy, legacy.total_energy, "{name}");
        assert_eq!(sharded.makespan, legacy.makespan, "{name}");
        assert_eq!(sharded.completed, legacy.completed, "{name}");
    }
}

#[test]
fn sharded_executor_is_reproducible_across_invocations() {
    let specs = specs();
    let cfg = config();
    let a = run_fleet(&specs, strategy("slack-proportional").as_mut(), &cfg);
    let b = run_fleet(&specs, strategy("slack-proportional").as_mut(), &cfg);
    assert_eq!(record_bytes(&a), record_bytes(&b));
}

#[test]
fn hetero_fleet_paths_are_byte_identical() {
    // The determinism contract holds for hierarchical nodes too: an
    // 8-node CPU+GPU fleet (device traces included in the JSON) must be
    // byte-identical across the sharded all-core, forced single-thread and
    // legacy per-node-thread paths.
    use powerctl::control::node_budget::DeviceSplitSpec;
    use powerctl::sim::cluster::Cluster;

    let cluster = Cluster::get(ClusterId::Gros);
    let specs: Vec<NodeSpec> = (0..8)
        .map(|_| NodeSpec {
            cluster: ClusterId::Gros,
            model: noise_free_model(ClusterId::Gros),
            policy: NodePolicySpec::Static,
            hardware: NodeHardware::cpu_gpu(&cluster, DeviceSplitSpec::SlackShift, 0.15),
        })
        .collect();
    let base = FleetConfig {
        budget: 8.0 * 360.0,
        period: 1.0,
        realloc_every: 5,
        total_beats: 600,
        max_time: 120.0,
        seed: 11,
        threads: None,
    };
    let sharded = run_fleet(&specs, strategy("slack-proportional").as_mut(), &base);
    let single_cfg = FleetConfig {
        threads: Some(1),
        ..base.clone()
    };
    let single = run_fleet(&specs, strategy("slack-proportional").as_mut(), &single_cfg);
    let legacy = run_fleet_threaded(&specs, strategy("slack-proportional").as_mut(), &base);

    for r in &sharded.records {
        assert_eq!(r.devices.len(), 2, "node {} missing device traces", r.node_id);
    }
    let a = record_bytes(&sharded);
    assert!(a == record_bytes(&single), "hetero: sharded != single-thread");
    assert!(a == record_bytes(&legacy), "hetero: sharded != legacy");
    assert_eq!(sharded.limits_trace, legacy.limits_trace);
}
