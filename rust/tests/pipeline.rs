//! Integration tests: the full workflow across modules — identification →
//! tuning → closed loop → evaluation — exactly as the CLI drives it.

use powerctl::control::baseline::{PiPolicy, Uncontrolled};
use powerctl::coordinator::experiment::run_closed_loop;
use powerctl::experiments::{fig6, fig7, identify, Ctx, Scale};
use powerctl::sim::cluster::{Cluster, ClusterId};

fn ctx(tag: &str) -> Ctx {
    Ctx::new(
        std::env::temp_dir().join(format!("powerctl-it-{tag}")),
        1234,
        Scale::Fast,
    )
}

#[test]
fn identify_then_control_all_clusters() {
    // The paper's complete workflow must hold on every cluster: identify
    // from simulated campaigns, tune, converge to the setpoint band.
    let ctx = ctx("all");
    for id in ClusterId::ALL {
        let ident = identify(&ctx, id);
        let cluster = Cluster::get(id);
        let (mut policy, sp) = fig6::make_pi(&ident, 0.15);
        let rec = run_closed_loop(&cluster, &mut policy, sp, 0.15, &ctx.run_config(), 99);
        assert!(rec.completed, "{id}: did not complete");
        assert!(rec.beats >= ctx.scale.total_beats(), "{id}: beats");
        // Mean cap must have come down from the rail on all clusters.
        assert!(
            rec.pcap.time_mean() < cluster.pcap_max - 1.0,
            "{id}: cap never moved ({:.1} W mean)",
            rec.pcap.time_mean()
        );
    }
    let _ = std::fs::remove_dir_all(&ctx.out_dir);
}

#[test]
fn headline_tradeoff_on_gros() {
    // The paper's headline: ε = 0.1 on gros saves ~22 % energy for ~7 %
    // time. Bands widened for the Fast campaign scale.
    let ctx = ctx("headline");
    let ident = identify(&ctx, ClusterId::Gros);
    let s = fig7::run_cluster(&ctx, &ident);
    let (dt, de) = s.deltas_at(0.1).expect("ε=0.1 present");
    assert!((5.0..35.0).contains(&de), "energy saving {de}% out of band");
    assert!((-2.0..20.0).contains(&dt), "slowdown {dt}% out of band");
    let _ = std::fs::remove_dir_all(&ctx.out_dir);
}

#[test]
fn controlled_never_slower_than_epsilon_promise_by_much() {
    // ε is a *performance-degradation bound*: measured slowdown should not
    // wildly exceed it on the stable cluster (controller promise).
    let ctx = ctx("promise");
    let ident = identify(&ctx, ClusterId::Gros);
    let cluster = Cluster::get(ClusterId::Gros);
    let cfg = ctx.run_config();
    let mut base = Uncontrolled {
        pcap_max: cluster.pcap_max,
    };
    let b = run_closed_loop(&cluster, &mut base, f64::NAN, 0.0, &cfg, 5);
    for eps in [0.05, 0.1, 0.2] {
        let (mut policy, sp) = fig6::make_pi(&ident, eps);
        let rec = run_closed_loop(&cluster, &mut policy, sp, eps, &cfg, 5);
        let slowdown = rec.exec_time / b.exec_time - 1.0;
        assert!(
            slowdown < eps + 0.10,
            "ε={eps}: slowdown {slowdown:.3} breaks the degradation promise"
        );
    }
    let _ = std::fs::remove_dir_all(&ctx.out_dir);
}

#[test]
fn seeds_reproduce_exactly() {
    // Bit-for-bit reproducibility of a full closed-loop run.
    let ctx = ctx("repro");
    let ident = identify(&ctx, ClusterId::Dahu);
    let cluster = Cluster::get(ClusterId::Dahu);
    let run = || {
        let (mut policy, sp) = fig6::make_pi(&ident, 0.15);
        run_closed_loop(&cluster, &mut policy, sp, 0.15, &ctx.run_config(), 777)
    };
    let a = run();
    let b = run();
    assert_eq!(a.exec_time, b.exec_time);
    assert_eq!(a.energy, b.energy);
    assert_eq!(a.progress.values, b.progress.values);
    let _ = std::fs::remove_dir_all(&ctx.out_dir);
}

#[test]
fn pi_beats_static_cap_at_matched_energy() {
    // The feedback claim: against a static cap chosen to consume a similar
    // energy, the PI (which only throttles when progress allows) should not
    // be substantially slower.
    let ctx = ctx("static");
    let ident = identify(&ctx, ClusterId::Gros);
    let cluster = Cluster::get(ClusterId::Gros);
    let cfg = ctx.run_config();
    let (mut pi, sp) = fig6::make_pi(&ident, 0.1);
    let pi_rec = run_closed_loop(&cluster, &mut pi, sp, 0.1, &cfg, 31);

    // Find the static cap with closest energy.
    let mut best: Option<(f64, f64)> = None; // (|ΔE|, exec_time)
    for cap in [60.0, 70.0, 80.0, 90.0, 100.0] {
        let mut p = powerctl::control::baseline::StaticCap { pcap: cap };
        let rec = run_closed_loop(&cluster, &mut p, f64::NAN, f64::NAN, &cfg, 31);
        let d = (rec.energy - pi_rec.energy).abs();
        if best.map(|(bd, _)| d < bd).unwrap_or(true) {
            best = Some((d, rec.exec_time));
        }
    }
    let (_, static_time) = best.unwrap();
    assert!(
        pi_rec.exec_time < static_time * 1.15,
        "PI {:.1}s vs matched static {:.1}s",
        pi_rec.exec_time,
        static_time
    );
    let _ = std::fs::remove_dir_all(&ctx.out_dir);
}
