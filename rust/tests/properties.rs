//! Cross-module property tests (util::check): system-level invariants that
//! must hold for arbitrary seeds, caps, and measurement sequences.

use powerctl::control::pi::{PiConfig, PiController};
use powerctl::coordinator::progress::ProgressAggregator;
use powerctl::experiments::{identify, Ctx, Scale};
use powerctl::ident::static_model::{StaticModel, StaticPoint};
use powerctl::ident::DynamicModel;
use powerctl::sim::cluster::{Cluster, ClusterId};
use powerctl::sim::node::NodeSim;
use powerctl::util::check::{check, close};
use powerctl::util::rng::Pcg64;
use powerctl::util::stats;

fn model_for(id: ClusterId) -> DynamicModel {
    let c = Cluster::get(id);
    let points: Vec<StaticPoint> = (0..50)
        .map(|i| {
            let pcap = c.pcap_min + i as f64 * ((c.pcap_max - c.pcap_min) / 49.0);
            StaticPoint {
                pcap,
                power: c.expected_power(pcap),
                progress: c.static_progress(pcap),
            }
        })
        .collect();
    DynamicModel {
        static_model: StaticModel::fit(&points),
        tau: c.tau,
        rmse: 0.0,
    }
}

#[test]
fn prop_controller_output_in_actuator_range() {
    // For ANY ε and ANY measurement sequence, every emitted cap is valid.
    check(101, 64, |rng| {
        let eps = rng.uniform(0.0, 0.5);
        let n = 20 + rng.below(80) as usize;
        let meas: Vec<f64> = (0..n).map(|_| rng.uniform(-50.0, 500.0)).collect();
        (eps, meas)
    }, |(eps, meas)| {
        let m = model_for(ClusterId::Gros);
        let cfg = PiConfig::from_model(&m, 10.0, 40.0, 120.0);
        let mut ctl = PiController::new(m, cfg, *eps);
        for (i, &p) in meas.iter().enumerate() {
            let cap = ctl.step(i as f64, p);
            if !(40.0..=120.0).contains(&cap) || !cap.is_finite() {
                return Err(format!("cap {cap} out of range at step {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_linearization_roundtrip() {
    // delinearize(linearize(pcap)) == pcap over the actuator range, for
    // every cluster's fitted model.
    check(102, 128, |rng| {
        let id = *rng.choose(&ClusterId::ALL);
        let pcap = rng.uniform(40.0, 120.0);
        (id, pcap)
    }, |(id, pcap)| {
        let s = model_for(*id).static_model;
        close(s.delinearize_pcap(s.linearize_pcap(*pcap)), *pcap, 1e-9)
    });
}

#[test]
fn prop_plant_steady_progress_monotone_in_cap() {
    // More power never slows STREAM down (static characteristic is
    // nondecreasing), whatever the cluster.
    check(103, 128, |rng| {
        let id = *rng.choose(&ClusterId::ALL);
        let a = rng.uniform(40.0, 120.0);
        let b = rng.uniform(40.0, 120.0);
        (id, a.min(b), a.max(b))
    }, |(id, lo, hi)| {
        let c = Cluster::get(*id);
        if c.static_progress(*hi) >= c.static_progress(*lo) - 1e-12 {
            Ok(())
        } else {
            Err(format!("progress({hi}) < progress({lo})"))
        }
    });
}

#[test]
fn prop_energy_counter_additive_and_monotone() {
    // Node energy is nondecreasing and consistent across step splits.
    check(104, 32, |rng| {
        let seed = rng.next_u64();
        let cap = rng.uniform(40.0, 120.0);
        let steps = 5 + rng.below(20) as usize;
        (seed, cap, steps)
    }, |(seed, cap, steps)| {
        let mut node = NodeSim::new(Cluster::get(ClusterId::Dahu), *seed);
        node.set_pcap(*cap);
        let mut last = 0.0;
        for _ in 0..*steps {
            let s = node.step(0.7);
            if s.energy < last {
                return Err(format!("energy decreased: {} -> {}", last, s.energy));
            }
            last = s.energy;
        }
        Ok(())
    });
}

#[test]
fn prop_median_between_min_max_and_robust() {
    check(105, 256, |rng| {
        let n = 1 + rng.below(200) as usize;
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform(-1e3, 1e3)).collect();
        xs
    }, |xs| {
        let m = stats::median(xs);
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        if m < lo || m > hi {
            return Err(format!("median {m} outside [{lo}, {hi}]"));
        }
        // Outlier robustness: adding one huge value moves the median by at
        // most one order statistic.
        let mut with_outlier = xs.clone();
        with_outlier.push(1e12);
        let m2 = stats::median(&with_outlier);
        if m2 < lo || m2 > hi + (hi - lo) {
            return Err(format!("median not robust: {m} -> {m2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_progress_aggregator_matches_direct_median() {
    // Feeding a batch of beats in one window must equal the median of the
    // inter-arrival frequencies computed directly.
    check(106, 64, |rng| {
        let n = 3 + rng.below(60) as usize;
        let mut t = 0.0;
        let beats: Vec<f64> = (0..n)
            .map(|_| {
                t += rng.uniform(0.01, 0.5);
                t
            })
            .collect();
        beats
    }, |beats| {
        let mut agg = ProgressAggregator::new();
        agg.ingest(beats);
        let got = agg.sample();
        let freqs: Vec<f64> = beats.windows(2).map(|w| 1.0 / (w[1] - w[0])).collect();
        close(got, stats::median(&freqs), 1e-9)
    });
}

#[test]
fn prop_lm_recovers_random_saturating_curves() {
    // The identification pipeline recovers randomly drawn plant parameters
    // from clean data — LM is not just tuned to the three paper clusters.
    check(107, 16, |rng| {
        let k_l = rng.uniform(10.0, 120.0);
        let alpha = rng.uniform(0.01, 0.08);
        let beta = rng.uniform(15.0, 38.0);
        (k_l, alpha, beta)
    }, |(k_l, alpha, beta)| {
        let points: Vec<StaticPoint> = (0..60)
            .map(|i| {
                let pcap = 40.0 + i as f64 * (80.0 / 59.0);
                let power = 0.9 * pcap + 2.0;
                StaticPoint {
                    pcap,
                    power,
                    progress: k_l * (1.0 - (-alpha * (power - beta)).exp()),
                }
            })
            .collect();
        let m = StaticModel::fit(&points);
        close(m.k_l, *k_l, 0.05)
            .and_then(|_| close(m.alpha, *alpha, 0.1))
            .and_then(|_| close(m.beta, *beta, 0.1))
    });
}

#[test]
fn prop_identified_controller_converges_for_any_epsilon() {
    // End-to-end: identify once, then for arbitrary ε the closed loop on a
    // clean plant settles within the tolerance band.
    let ctx = Ctx::new(std::env::temp_dir().join("powerctl-prop-conv"), 9, Scale::Fast);
    let ident = identify(&ctx, ClusterId::Gros);
    let plant = model_for(ClusterId::Gros);
    check(108, 12, |rng| rng.uniform(0.02, 0.4), |eps| {
        let cfg = PiConfig::from_model(&ident.model, 10.0, 40.0, 120.0);
        let mut ctl = PiController::new(ident.model.clone(), cfg, *eps);
        let mut progress = plant.static_model.predict(120.0);
        for i in 0..300 {
            let cap = ctl.step(i as f64, progress);
            progress = plant.predict_next(progress, cap, 1.0);
        }
        let sp = ctl.setpoint();
        // Allow identification error: settle within 5 % of the setpoint OR
        // at the rail if the setpoint exceeds the plant's reach.
        if (progress - sp).abs() <= 0.05 * sp + 0.2 {
            Ok(())
        } else {
            Err(format!("ε={eps}: settled {progress} vs setpoint {sp}"))
        }
    });
    let _ = std::fs::remove_dir_all(std::env::temp_dir().join("powerctl-prop-conv"));
}

#[test]
fn prop_run_records_internally_consistent() {
    // Any closed-loop run's record satisfies basic accounting identities.
    let ctx = Ctx::new(std::env::temp_dir().join("powerctl-prop-rec"), 10, Scale::Fast);
    let ident = identify(&ctx, ClusterId::Dahu);
    let cluster = Cluster::get(ClusterId::Dahu);
    check(109, 8, |rng| (rng.uniform(0.0, 0.4), rng.next_u64()), |(eps, seed)| {
        let (mut policy, sp) = powerctl::experiments::fig6::make_pi(&ident, *eps);
        let rec = powerctl::coordinator::experiment::run_closed_loop(
            &cluster,
            &mut policy,
            sp,
            *eps,
            &ctx.run_config(),
            *seed,
        );
        if !rec.completed {
            return Err("did not complete".into());
        }
        if rec.energy <= 0.0 {
            return Err("no energy recorded".into());
        }
        if rec.exec_time <= 0.0 || rec.exec_time > 3_600.0 {
            return Err(format!("exec_time {}", rec.exec_time));
        }
        // Sampled series aligned.
        if rec.pcap.len() != rec.progress.len() || rec.power.len() != rec.progress.len() {
            return Err("series length mismatch".into());
        }
        // Energy sanity: between min and max possible power draw.
        let t = rec.pcap.times.last().unwrap() + 1.0;
        let sockets = cluster.sockets as f64;
        let pmax = cluster.expected_power(120.0) * sockets * 1.2;
        if rec.energy > pmax * t {
            return Err(format!("energy {} exceeds physical bound", rec.energy));
        }
        Ok(())
    });
    let _ = std::fs::remove_dir_all(std::env::temp_dir().join("powerctl-prop-rec"));
}

#[test]
fn prop_rng_split_streams_uncorrelated() {
    // Campaign seeding soundness: children of a split never collide.
    check(110, 32, |rng| rng.next_u64(), |seed| {
        let mut root = Pcg64::seeded(*seed);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let xa: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        if xa == xb {
            return Err("split streams identical".into());
        }
        let collisions = xa.iter().filter(|x| xb.contains(x)).count();
        if collisions > 0 {
            return Err(format!("{collisions} collisions"));
        }
        Ok(())
    });
}
