//! Kernel-vs-classic equivalence: the batched shard-major SoA stepping
//! kernel — including the **resident** executor mode, where the kernel
//! arrays are the persistent home of device state across control periods
//! — must produce **byte-identical** `RunRecord` JSON to the classic
//! per-node scalar loops, for every fleet shape we can throw at it.
//!
//! `SimPath::Batched` through `run_fleet_with_path` exercises the full
//! resident protocol: adopt-once at construction, one kernel invocation
//! per shard per period — **lane-exact SIMD sub-steps** — staged-sensor
//! consumption by the engines, and (past the default cadence)
//! measured-load rebalancing migrations. `SimPath::BatchedScalar` is the
//! same resident protocol restricted to scalar sub-steps, so the suite
//! triangulates three ways: SIMD vs scalar-resident isolates the lane
//! path, scalar-resident vs classic isolates residency and layout. The
//! non-lane-multiple cases (1, 3, 5, 7 device slots in one shard) pin the
//! remainder handling.
//!
//! Together with `tests/fleet_equivalence.rs` (sharded vs legacy
//! executor), `tests/scheduler_determinism.rs` (worker counts ×
//! rebalancing) and `tests/hetero_equivalence.rs` (hierarchy collapse),
//! this pins the full determinism contract: neither the execution
//! mechanism, the stepping layout, nor state residency may change bytes —
//! only wall time.

use powerctl::control::budget::{BudgetPolicy, GreedyRepack, SlackProportional, UniformBudget};
use powerctl::control::node_budget::DeviceSplitSpec;
use powerctl::fleet::node::noise_free_model;
use powerctl::fleet::{
    run_fleet_with_path, FleetConfig, FleetOutcome, NodeHardware, NodePolicySpec, NodeSpec, SimPath,
};
use powerctl::sim::cluster::{Cluster, ClusterId};
use powerctl::sim::device::DeviceSpec;
use powerctl::sim::node::NodeSim;
use powerctl::util::rng::Pcg64;

fn record_bytes(out: &FleetOutcome) -> String {
    out.records
        .iter()
        .map(|r| r.to_json().dump())
        .collect::<Vec<_>>()
        .join("\n")
}

fn strategy(name: &str) -> Box<dyn BudgetPolicy> {
    match name {
        "uniform" => Box::new(UniformBudget),
        "slack-proportional" => Box::new(SlackProportional::default()),
        "greedy-repack" => Box::new(GreedyRepack::default()),
        other => panic!("unknown strategy {other}"),
    }
}

#[test]
fn node_kernel_matches_classic_on_every_cluster() {
    // Sim-layer pin: one node stepped by its own batched kernel emits the
    // same sensors and heartbeat bytes as classic scalar stepping.
    for id in [ClusterId::Gros, ClusterId::Dahu, ClusterId::Yeti] {
        let cluster = Cluster::get(id);
        let mut kernel = NodeSim::new(cluster.clone(), 5);
        let mut classic = NodeSim::new(cluster.clone(), 5);
        classic.set_classic_stepping(true);
        kernel.set_pcap(90.0);
        classic.set_pcap(90.0);
        let mut ba = Vec::new();
        let mut bb = Vec::new();
        for i in 0..120 {
            ba.clear();
            bb.clear();
            let sa = kernel.step_into(1.0, &mut ba);
            let sb = classic.step_into(1.0, &mut bb);
            assert_eq!(sa.power, sb.power, "{id} step {i}: power");
            assert_eq!(sa.energy, sb.energy, "{id} step {i}: energy");
            assert_eq!(sa.time, sb.time, "{id} step {i}: time");
            assert_eq!(sa.true_progress, sb.true_progress, "{id} step {i}");
            assert_eq!(ba, bb, "{id} step {i}: heartbeats");
        }
        assert_eq!(kernel.beats(), classic.beats(), "{id}: beat totals");
    }
}

#[test]
fn hetero_node_kernel_matches_classic_per_device_sinks() {
    // Per-device attribution path: kernel vs classic stepping of a
    // CPU+GPU node through step_devices_into, including odd periods that
    // exercise the sub-step rounding.
    let cluster = Cluster::get(ClusterId::Yeti);
    let specs = [DeviceSpec::cpu(&cluster), DeviceSpec::gpu()];
    let mut kernel = NodeSim::hetero(cluster.clone(), &specs, 31);
    let mut classic = NodeSim::hetero(cluster.clone(), &specs, 31);
    classic.set_classic_stepping(true);
    let mut sa = vec![Vec::new(), Vec::new()];
    let mut sb = vec![Vec::new(), Vec::new()];
    for i in 0..80 {
        for s in sa.iter_mut().chain(sb.iter_mut()) {
            s.clear();
        }
        let dt = if i % 3 == 0 { 0.73 } else { 1.0 };
        let ra = kernel.step_devices_into(dt, &mut sa);
        let rb = classic.step_devices_into(dt, &mut sb);
        assert_eq!(ra.power, rb.power, "step {i}");
        assert_eq!(ra.energy, rb.energy, "step {i}");
        assert_eq!(sa, sb, "step {i}: per-device heartbeats");
    }
}

/// Draw a random fleet (mixed single-CPU and CPU+GPU hetero nodes over the
/// three clusters) plus a config with a tight-ish budget so reallocation
/// epochs actually move watts.
fn random_fleet(rng: &mut Pcg64) -> (Vec<NodeSpec>, FleetConfig) {
    let clusters = [ClusterId::Gros, ClusterId::Dahu, ClusterId::Yeti];
    let n = 2 + rng.below(6) as usize;
    let mut budget = 0.0;
    let specs: Vec<NodeSpec> = (0..n)
        .map(|_| {
            let id = *rng.choose(&clusters);
            let cluster = Cluster::get(id);
            let hetero = rng.f64() < 0.4;
            if hetero {
                budget += 0.7 * (cluster.pcap_max + 400.0);
                NodeSpec {
                    cluster: id,
                    model: noise_free_model(id),
                    policy: NodePolicySpec::Static,
                    hardware: NodeHardware::cpu_gpu(
                        &cluster,
                        *rng.choose(&[
                            DeviceSplitSpec::Even,
                            DeviceSplitSpec::SlackShift,
                            DeviceSplitSpec::GreedyRepack,
                        ]),
                        rng.uniform(0.05, 0.3),
                    ),
                }
            } else {
                budget += rng.uniform(0.7, 0.95) * cluster.pcap_max;
                NodeSpec {
                    cluster: id,
                    model: noise_free_model(id),
                    policy: NodePolicySpec::Pi {
                        epsilon: rng.uniform(0.0, 0.3),
                    },
                    hardware: NodeHardware::SingleCpu,
                }
            }
        })
        .collect();
    let cfg = FleetConfig {
        budget,
        period: 1.0,
        realloc_every: 1 + rng.below(5),
        total_beats: 200 + rng.below(300),
        max_time: 90.0,
        seed: rng.next_u64(),
        threads: None,
    };
    (specs, cfg)
}

#[test]
fn random_fleets_kernel_and_classic_records_byte_identical() {
    // Property test (satellite): across random fleet configs — mixed
    // single-device and hetero nodes, all three budget policies — the
    // kernel path's RunRecord::to_json must equal the classic path's,
    // byte for byte.
    let mut rng = Pcg64::seeded(0xC0FFEE);
    for case in 0..4 {
        let (specs, cfg) = random_fleet(&mut rng);
        for name in ["uniform", "slack-proportional", "greedy-repack"] {
            let batched =
                run_fleet_with_path(&specs, strategy(name).as_mut(), &cfg, SimPath::Batched);
            let classic =
                run_fleet_with_path(&specs, strategy(name).as_mut(), &cfg, SimPath::Classic);
            assert_eq!(
                record_bytes(&batched),
                record_bytes(&classic),
                "case {case} strategy {name}: kernel != classic ({} nodes, seed {})",
                specs.len(),
                cfg.seed
            );
            assert_eq!(
                batched.limits_trace, classic.limits_trace,
                "case {case} strategy {name}: ceiling traces diverge"
            );
        }
    }
}

/// Fleet with an exact node mix — `singles` single-CPU nodes (1 device
/// slot each) + `heteros` CPU+GPU nodes (2 slots each) — on ONE worker
/// thread, so the whole fleet is a single shard and the slot total is
/// exactly the kernel width the lane walk sees.
fn fleet_with_shape(rng: &mut Pcg64, singles: usize, heteros: usize) -> (Vec<NodeSpec>, FleetConfig) {
    let clusters = [ClusterId::Gros, ClusterId::Dahu, ClusterId::Yeti];
    let mut budget = 0.0;
    let mut specs = Vec::new();
    for _ in 0..singles {
        let id = *rng.choose(&clusters);
        let cluster = Cluster::get(id);
        budget += rng.uniform(0.7, 0.95) * cluster.pcap_max;
        specs.push(NodeSpec {
            cluster: id,
            model: noise_free_model(id),
            policy: NodePolicySpec::Pi {
                epsilon: rng.uniform(0.0, 0.3),
            },
            hardware: NodeHardware::SingleCpu,
        });
    }
    for _ in 0..heteros {
        let id = *rng.choose(&clusters);
        let cluster = Cluster::get(id);
        budget += 0.7 * (cluster.pcap_max + 400.0);
        specs.push(NodeSpec {
            cluster: id,
            model: noise_free_model(id),
            policy: NodePolicySpec::Static,
            hardware: NodeHardware::cpu_gpu(
                &cluster,
                DeviceSplitSpec::SlackShift,
                rng.uniform(0.05, 0.3),
            ),
        });
    }
    let cfg = FleetConfig {
        budget,
        period: 1.0,
        realloc_every: 2,
        total_beats: 150 + rng.below(150),
        max_time: 60.0,
        seed: rng.next_u64(),
        threads: Some(1),
    };
    (specs, cfg)
}

#[test]
fn non_lane_multiple_slot_counts_triangulate_paths_byte_identical() {
    // SIMD property pin (satellite): single-shard fleets with 1, 3
    // (= lanes − 1), 5 (= lanes + 1) and 7 device slots — never a
    // multiple of the 4-lane width — must produce byte-identical records
    // on the SIMD (Batched), scalar-resident (BatchedScalar) and classic
    // paths. The odd totals force lane walks ending in every tail length.
    let mut rng = Pcg64::seeded(0x1A9E5);
    for (case, &(singles, heteros)) in
        [(1usize, 0usize), (1, 1), (3, 1), (3, 2)].iter().enumerate()
    {
        let (specs, cfg) = fleet_with_shape(&mut rng, singles, heteros);
        for name in ["uniform", "slack-proportional"] {
            let simd =
                run_fleet_with_path(&specs, strategy(name).as_mut(), &cfg, SimPath::Batched);
            let scalar =
                run_fleet_with_path(&specs, strategy(name).as_mut(), &cfg, SimPath::BatchedScalar);
            let classic =
                run_fleet_with_path(&specs, strategy(name).as_mut(), &cfg, SimPath::Classic);
            let bytes = record_bytes(&simd);
            assert_eq!(
                bytes,
                record_bytes(&scalar),
                "case {case} ({singles}+{heteros} nodes, {} slots) {name}: simd != scalar-resident",
                singles + 2 * heteros
            );
            assert_eq!(
                bytes,
                record_bytes(&classic),
                "case {case} ({singles}+{heteros} nodes) {name}: simd != classic"
            );
            assert_eq!(
                simd.limits_trace, scalar.limits_trace,
                "case {case} {name}: ceiling traces diverge"
            );
        }
    }
}

#[test]
fn random_fleets_simd_vs_scalar_resident_byte_identical() {
    // Multi-shard variant: random mixed fleets on the default thread
    // count, so lanes fragment across shards and rebalancing stays live.
    // The SIMD and scalar-resident paths share the resident protocol —
    // any byte difference isolates the lane arithmetic itself.
    let mut rng = Pcg64::seeded(0xBEEF5);
    for case in 0..3 {
        let (specs, cfg) = random_fleet(&mut rng);
        let simd = run_fleet_with_path(
            &specs,
            strategy("greedy-repack").as_mut(),
            &cfg,
            SimPath::Batched,
        );
        let scalar = run_fleet_with_path(
            &specs,
            strategy("greedy-repack").as_mut(),
            &cfg,
            SimPath::BatchedScalar,
        );
        assert_eq!(
            record_bytes(&simd),
            record_bytes(&scalar),
            "case {case} ({} nodes, seed {})",
            specs.len(),
            cfg.seed
        );
    }
}

#[test]
fn lane_ops_bitwise_equal_scalar_through_public_api() {
    // Public-API spot check of the lane-exactness contract the kernel
    // path is built on (the exhaustive per-op suite lives in sim::simd).
    use powerctl::sim::simd::{F64x4, LANES};
    assert_eq!(LANES, 4);
    let a = [0.1, -0.0, 1e300, -7.5];
    let b = [2.0, 3.5, -1e300, 0.25];
    let v = F64x4(a) * F64x4(b) + F64x4(a);
    for i in 0..LANES {
        assert_eq!(v.0[i].to_bits(), (a[i] * b[i] + a[i]).to_bits(), "lane {i}");
    }
    let c = (F64x4(a) - F64x4(b)).clamp(-1.0, 1.0).max_scalar(0.0);
    for i in 0..LANES {
        let want = (a[i] - b[i]).clamp(-1.0, 1.0).max(0.0);
        assert_eq!(c.0[i].to_bits(), want.to_bits(), "lane {i}");
    }
}

#[test]
fn kernel_path_is_reproducible_across_invocations() {
    let mut rng = Pcg64::seeded(77);
    let (specs, cfg) = random_fleet(&mut rng);
    let a = run_fleet_with_path(&specs, strategy("uniform").as_mut(), &cfg, SimPath::Batched);
    let b = run_fleet_with_path(&specs, strategy("uniform").as_mut(), &cfg, SimPath::Batched);
    assert_eq!(record_bytes(&a), record_bytes(&b));
}

#[test]
fn long_horizon_resident_run_crosses_rebalance_epochs_byte_identical() {
    // A mixed fleet driven far past the executor's default rebalance
    // cadence (32 periods): several decision epochs — and possibly
    // migrations, which regather/readopt every node's resident state —
    // happen mid-run. The classic path must still match byte for byte.
    let cluster = Cluster::get(ClusterId::Gros);
    let mut specs: Vec<NodeSpec> = (0..6)
        .map(|_| NodeSpec {
            cluster: ClusterId::Gros,
            model: noise_free_model(ClusterId::Gros),
            policy: NodePolicySpec::Pi { epsilon: 0.15 },
            hardware: NodeHardware::SingleCpu,
        })
        .collect();
    specs.push(NodeSpec {
        cluster: ClusterId::Gros,
        model: noise_free_model(ClusterId::Gros),
        policy: NodePolicySpec::Static,
        hardware: NodeHardware::cpu_gpu(&cluster, DeviceSplitSpec::SlackShift, 0.15),
    });
    let cfg = FleetConfig {
        budget: 6.0 * 85.0 + 360.0,
        period: 1.0,
        realloc_every: 5,
        total_beats: u64::MAX, // run the whole horizon
        max_time: 150.0,
        seed: 13,
        threads: None,
    };
    let batched = run_fleet_with_path(
        &specs,
        strategy("slack-proportional").as_mut(),
        &cfg,
        SimPath::Batched,
    );
    let classic = run_fleet_with_path(
        &specs,
        strategy("slack-proportional").as_mut(),
        &cfg,
        SimPath::Classic,
    );
    assert_eq!(record_bytes(&batched), record_bytes(&classic));
    assert_eq!(batched.limits_trace, classic.limits_trace);
}
