//! Kernel-vs-classic equivalence: the batched shard-major SoA stepping
//! kernel — including the **resident** executor mode, where the kernel
//! arrays are the persistent home of device state across control periods
//! — must produce **byte-identical** `RunRecord` JSON to the classic
//! per-node scalar loops, for every fleet shape we can throw at it.
//!
//! `SimPath::Batched` through `run_fleet_with_path` exercises the full
//! resident protocol: adopt-once at construction, one kernel invocation
//! per shard per period, staged-sensor consumption by the engines, and
//! (past the default cadence) measured-load rebalancing migrations.
//!
//! Together with `tests/fleet_equivalence.rs` (sharded vs legacy
//! executor), `tests/scheduler_determinism.rs` (worker counts ×
//! rebalancing) and `tests/hetero_equivalence.rs` (hierarchy collapse),
//! this pins the full determinism contract: neither the execution
//! mechanism, the stepping layout, nor state residency may change bytes —
//! only wall time.

use powerctl::control::budget::{BudgetPolicy, GreedyRepack, SlackProportional, UniformBudget};
use powerctl::control::node_budget::DeviceSplitSpec;
use powerctl::fleet::node::noise_free_model;
use powerctl::fleet::{
    run_fleet_with_path, FleetConfig, FleetOutcome, NodeHardware, NodePolicySpec, NodeSpec, SimPath,
};
use powerctl::sim::cluster::{Cluster, ClusterId};
use powerctl::sim::device::DeviceSpec;
use powerctl::sim::node::NodeSim;
use powerctl::util::rng::Pcg64;

fn record_bytes(out: &FleetOutcome) -> String {
    out.records
        .iter()
        .map(|r| r.to_json().dump())
        .collect::<Vec<_>>()
        .join("\n")
}

fn strategy(name: &str) -> Box<dyn BudgetPolicy> {
    match name {
        "uniform" => Box::new(UniformBudget),
        "slack-proportional" => Box::new(SlackProportional::default()),
        "greedy-repack" => Box::new(GreedyRepack::default()),
        other => panic!("unknown strategy {other}"),
    }
}

#[test]
fn node_kernel_matches_classic_on_every_cluster() {
    // Sim-layer pin: one node stepped by its own batched kernel emits the
    // same sensors and heartbeat bytes as classic scalar stepping.
    for id in [ClusterId::Gros, ClusterId::Dahu, ClusterId::Yeti] {
        let cluster = Cluster::get(id);
        let mut kernel = NodeSim::new(cluster.clone(), 5);
        let mut classic = NodeSim::new(cluster.clone(), 5);
        classic.set_classic_stepping(true);
        kernel.set_pcap(90.0);
        classic.set_pcap(90.0);
        let mut ba = Vec::new();
        let mut bb = Vec::new();
        for i in 0..120 {
            ba.clear();
            bb.clear();
            let sa = kernel.step_into(1.0, &mut ba);
            let sb = classic.step_into(1.0, &mut bb);
            assert_eq!(sa.power, sb.power, "{id} step {i}: power");
            assert_eq!(sa.energy, sb.energy, "{id} step {i}: energy");
            assert_eq!(sa.time, sb.time, "{id} step {i}: time");
            assert_eq!(sa.true_progress, sb.true_progress, "{id} step {i}");
            assert_eq!(ba, bb, "{id} step {i}: heartbeats");
        }
        assert_eq!(kernel.beats(), classic.beats(), "{id}: beat totals");
    }
}

#[test]
fn hetero_node_kernel_matches_classic_per_device_sinks() {
    // Per-device attribution path: kernel vs classic stepping of a
    // CPU+GPU node through step_devices_into, including odd periods that
    // exercise the sub-step rounding.
    let cluster = Cluster::get(ClusterId::Yeti);
    let specs = [DeviceSpec::cpu(&cluster), DeviceSpec::gpu()];
    let mut kernel = NodeSim::hetero(cluster.clone(), &specs, 31);
    let mut classic = NodeSim::hetero(cluster.clone(), &specs, 31);
    classic.set_classic_stepping(true);
    let mut sa = vec![Vec::new(), Vec::new()];
    let mut sb = vec![Vec::new(), Vec::new()];
    for i in 0..80 {
        for s in sa.iter_mut().chain(sb.iter_mut()) {
            s.clear();
        }
        let dt = if i % 3 == 0 { 0.73 } else { 1.0 };
        let ra = kernel.step_devices_into(dt, &mut sa);
        let rb = classic.step_devices_into(dt, &mut sb);
        assert_eq!(ra.power, rb.power, "step {i}");
        assert_eq!(ra.energy, rb.energy, "step {i}");
        assert_eq!(sa, sb, "step {i}: per-device heartbeats");
    }
}

/// Draw a random fleet (mixed single-CPU and CPU+GPU hetero nodes over the
/// three clusters) plus a config with a tight-ish budget so reallocation
/// epochs actually move watts.
fn random_fleet(rng: &mut Pcg64) -> (Vec<NodeSpec>, FleetConfig) {
    let clusters = [ClusterId::Gros, ClusterId::Dahu, ClusterId::Yeti];
    let n = 2 + rng.below(6) as usize;
    let mut budget = 0.0;
    let specs: Vec<NodeSpec> = (0..n)
        .map(|_| {
            let id = *rng.choose(&clusters);
            let cluster = Cluster::get(id);
            let hetero = rng.f64() < 0.4;
            if hetero {
                budget += 0.7 * (cluster.pcap_max + 400.0);
                NodeSpec {
                    cluster: id,
                    model: noise_free_model(id),
                    policy: NodePolicySpec::Static,
                    hardware: NodeHardware::cpu_gpu(
                        &cluster,
                        *rng.choose(&[
                            DeviceSplitSpec::Even,
                            DeviceSplitSpec::SlackShift,
                            DeviceSplitSpec::GreedyRepack,
                        ]),
                        rng.uniform(0.05, 0.3),
                    ),
                }
            } else {
                budget += rng.uniform(0.7, 0.95) * cluster.pcap_max;
                NodeSpec {
                    cluster: id,
                    model: noise_free_model(id),
                    policy: NodePolicySpec::Pi {
                        epsilon: rng.uniform(0.0, 0.3),
                    },
                    hardware: NodeHardware::SingleCpu,
                }
            }
        })
        .collect();
    let cfg = FleetConfig {
        budget,
        period: 1.0,
        realloc_every: 1 + rng.below(5),
        total_beats: 200 + rng.below(300),
        max_time: 90.0,
        seed: rng.next_u64(),
        threads: None,
    };
    (specs, cfg)
}

#[test]
fn random_fleets_kernel_and_classic_records_byte_identical() {
    // Property test (satellite): across random fleet configs — mixed
    // single-device and hetero nodes, all three budget policies — the
    // kernel path's RunRecord::to_json must equal the classic path's,
    // byte for byte.
    let mut rng = Pcg64::seeded(0xC0FFEE);
    for case in 0..4 {
        let (specs, cfg) = random_fleet(&mut rng);
        for name in ["uniform", "slack-proportional", "greedy-repack"] {
            let batched =
                run_fleet_with_path(&specs, strategy(name).as_mut(), &cfg, SimPath::Batched);
            let classic =
                run_fleet_with_path(&specs, strategy(name).as_mut(), &cfg, SimPath::Classic);
            assert_eq!(
                record_bytes(&batched),
                record_bytes(&classic),
                "case {case} strategy {name}: kernel != classic ({} nodes, seed {})",
                specs.len(),
                cfg.seed
            );
            assert_eq!(
                batched.limits_trace, classic.limits_trace,
                "case {case} strategy {name}: ceiling traces diverge"
            );
        }
    }
}

#[test]
fn kernel_path_is_reproducible_across_invocations() {
    let mut rng = Pcg64::seeded(77);
    let (specs, cfg) = random_fleet(&mut rng);
    let a = run_fleet_with_path(&specs, strategy("uniform").as_mut(), &cfg, SimPath::Batched);
    let b = run_fleet_with_path(&specs, strategy("uniform").as_mut(), &cfg, SimPath::Batched);
    assert_eq!(record_bytes(&a), record_bytes(&b));
}

#[test]
fn long_horizon_resident_run_crosses_rebalance_epochs_byte_identical() {
    // A mixed fleet driven far past the executor's default rebalance
    // cadence (32 periods): several decision epochs — and possibly
    // migrations, which regather/readopt every node's resident state —
    // happen mid-run. The classic path must still match byte for byte.
    let cluster = Cluster::get(ClusterId::Gros);
    let mut specs: Vec<NodeSpec> = (0..6)
        .map(|_| NodeSpec {
            cluster: ClusterId::Gros,
            model: noise_free_model(ClusterId::Gros),
            policy: NodePolicySpec::Pi { epsilon: 0.15 },
            hardware: NodeHardware::SingleCpu,
        })
        .collect();
    specs.push(NodeSpec {
        cluster: ClusterId::Gros,
        model: noise_free_model(ClusterId::Gros),
        policy: NodePolicySpec::Static,
        hardware: NodeHardware::cpu_gpu(&cluster, DeviceSplitSpec::SlackShift, 0.15),
    });
    let cfg = FleetConfig {
        budget: 6.0 * 85.0 + 360.0,
        period: 1.0,
        realloc_every: 5,
        total_beats: u64::MAX, // run the whole horizon
        max_time: 150.0,
        seed: 13,
        threads: None,
    };
    let batched = run_fleet_with_path(
        &specs,
        strategy("slack-proportional").as_mut(),
        &cfg,
        SimPath::Batched,
    );
    let classic = run_fleet_with_path(
        &specs,
        strategy("slack-proportional").as_mut(),
        &cfg,
        SimPath::Classic,
    );
    assert_eq!(record_bytes(&batched), record_bytes(&classic));
    assert_eq!(batched.limits_trace, classic.limits_trace);
}
