//! Hardened-control-plane contracts under transport chaos.
//!
//! Four pins (DESIGN.md "Live control plane hardening"):
//!
//! 1. `chaos_empty_plan_identical` — an empty (or all-inert) [`ChaosPlan`]
//!    is **byte-identical** to the chaos-free path on all three `SimPath`s:
//!    the hardened plane costs nothing — not one RNG draw, not one JSON
//!    key — until a rule matches.
//! 2. A seeded chaos plan replays byte-identically across repeated runs
//!    *and* worker counts — disturbance draws are per-node streams, never
//!    scheduling-order dependent.
//! 3. The acceptance storm: 64 nodes under 10% loss + 10% duplication +
//!    50% reordering complete without panic, with disturbances logged on
//!    the records; and under a deterministic transport blackout every
//!    chaos-matched node walks the full degradation ladder (watchdog
//!    staleness → full-cap fallback → bumpless re-engage) while survivor
//!    bytes stay untouched under frozen ceilings.
//! 4. Retry backoff is seed-deterministic and deadline-capped — the same
//!    `(policy, seed)` decides the same sleep schedule, and cumulative
//!    backoff never exceeds the policy deadline.

use std::sync::{Arc, Mutex};

use powerctl::control::budget::{BudgetPolicy, FrozenLimits, SlackProportional};
use powerctl::coordinator::supervisor::{Actuator, RetryingActuator};
use powerctl::coordinator::{ChaosPlan, ChaosRegime};
use powerctl::experiments::chaos::storm_regime;
use powerctl::fleet::node::noise_free_model;
use powerctl::fleet::{
    run_fleet_with_chaos, run_fleet_with_path, FleetConfig, FleetOutcome, NodeHardware,
    NodePolicySpec, NodeSpec, SimPath,
};
use powerctl::sim::cluster::ClusterId;
use powerctl::sim::faults::{FaultEventKind, FaultPlan, NodeSelector};
use powerctl::util::retry::RetryPolicy;

fn specs(n: usize) -> Vec<NodeSpec> {
    let order = [ClusterId::Gros, ClusterId::Dahu];
    let models = [
        noise_free_model(ClusterId::Gros),
        noise_free_model(ClusterId::Dahu),
    ];
    (0..n)
        .map(|i| NodeSpec {
            cluster: order[i % 2],
            model: models[i % 2].clone(),
            policy: NodePolicySpec::Pi { epsilon: 0.15 },
            hardware: NodeHardware::SingleCpu,
        })
        .collect()
}

fn config(n: usize) -> FleetConfig {
    FleetConfig {
        budget: n as f64 * 85.0,
        period: 1.0,
        realloc_every: 5,
        total_beats: 300,
        max_time: 120.0,
        seed: 7,
        threads: None,
    }
}

fn record_bytes(out: &FleetOutcome) -> String {
    out.records
        .iter()
        .map(|r| r.to_json().dump())
        .collect::<Vec<_>>()
        .join("\n")
}

/// The CI grep gate anchors on this test name (see `.github/workflows/
/// ci.yml`): empty and all-inert chaos plans are byte-free no-ops on
/// every stepping path — the tick hot path pays one `Option` branch and
/// nothing else until chaos is armed.
#[test]
fn chaos_empty_plan_identical() {
    let specs = specs(12);
    let cfg = config(12);
    // "Inert": a rule present but with every channel at zero probability —
    // `link` compiles it to nothing.
    let inert = ChaosPlan::seeded(99).with_rule(NodeSelector::All, ChaosRegime::default());
    assert!(inert.is_empty());
    for path in [SimPath::Batched, SimPath::BatchedScalar, SimPath::Classic] {
        let clean = run_fleet_with_path(&specs, &mut SlackProportional::default(), &cfg, path);
        let empty = run_fleet_with_chaos(
            &specs,
            &mut SlackProportional::default(),
            &cfg,
            path,
            &FaultPlan::default(),
            &ChaosPlan::default(),
        );
        let inert_out = run_fleet_with_chaos(
            &specs,
            &mut SlackProportional::default(),
            &cfg,
            path,
            &FaultPlan::default(),
            &inert,
        );
        let a = record_bytes(&clean);
        assert!(
            a == record_bytes(&empty),
            "{path:?}: empty chaos plan changed bytes"
        );
        assert!(
            a == record_bytes(&inert_out),
            "{path:?}: all-inert chaos plan changed bytes"
        );
        assert_eq!(clean.limits_trace, empty.limits_trace, "{path:?}");
        assert_eq!(clean.limits_trace, inert_out.limits_trace, "{path:?}");
        assert!(
            !a.contains("\"faults\""),
            "{path:?}: clean records grew a faults key"
        );
    }
}

/// A seeded storm plan replays byte-identically across repeated runs and
/// worker counts: chaos draws come from per-node RNG streams split from
/// the plan seed, so shard scheduling can never leak into the bytes.
#[test]
fn seeded_chaos_replays_across_runs_and_worker_counts() {
    let n = 16;
    let specs = specs(n);
    let plan = ChaosPlan::seeded(0x57E0).with_rule(NodeSelector::All, storm_regime());
    let run = |threads: Option<usize>| {
        let mut cfg = config(n);
        cfg.threads = threads;
        let mut strat = SlackProportional::default();
        run_fleet_with_chaos(
            &specs,
            &mut strat,
            &cfg,
            SimPath::Batched,
            &FaultPlan::default(),
            &plan,
        )
    };
    let a = run(None);
    let bytes = record_bytes(&a);
    for threads in [None, Some(1), Some(4)] {
        let b = run(threads);
        assert_eq!(
            bytes,
            record_bytes(&b),
            "chaos replay diverged at threads={threads:?}"
        );
        assert_eq!(a.limits_trace, b.limits_trace, "threads={threads:?}");
    }
    // The storm actually disturbed something — the replay check above
    // would be vacuous on an accidentally-inert plan.
    assert!(
        bytes.contains("\"faults\""),
        "storm left no chaos events on any record"
    );
}

/// Acceptance storm: 64 nodes under 10% loss + 10% duplication + 50%
/// reordering. Every node completes its quota (completion runs on
/// ground-truth beats — chaos corrupts telemetry, not work), nothing
/// panics, and the disturbances are visible on the records.
#[test]
fn storm_64_nodes_completes_under_loss_dup_reorder() {
    let n = 64;
    let specs = specs(n);
    let cfg = config(n);
    let plan = ChaosPlan::seeded(0xC4A0).with_rule(NodeSelector::All, storm_regime());
    let mut strat = SlackProportional::default();
    let out = run_fleet_with_chaos(
        &specs,
        &mut strat,
        &cfg,
        SimPath::Batched,
        &FaultPlan::default(),
        &plan,
    );
    let mut disturbed_nodes = 0;
    for (i, r) in out.records.iter().enumerate() {
        assert!(r.completed, "node {i} did not complete under the storm");
        let chaos_events = r
            .faults
            .iter()
            .filter(|e| {
                matches!(
                    e.kind,
                    FaultEventKind::ChaosLoss
                        | FaultEventKind::ChaosDup
                        | FaultEventKind::ChaosReorder
                )
            })
            .count();
        if chaos_events > 0 {
            disturbed_nodes += 1;
        }
    }
    assert_eq!(
        disturbed_nodes, n,
        "a fleetwide 10%/10%/50% storm must disturb every node's telemetry"
    );
}

/// Deterministic blackout recovery: a delay-everything regime silences a
/// quarter of the fleet's telemetry for 10 s. Every matched node must walk
/// the full ladder — watchdog staleness, full-cap fallback after the
/// staleness window, bumpless re-engage once delayed beats flow — and
/// still complete. Under frozen ceilings, every unmatched node's record is
/// byte-identical to the chaos-free run.
#[test]
fn ladder_recovers_from_blackout_with_survivor_bytes_untouched() {
    let n = 16;
    let specs = specs(n);
    let cfg = config(n);
    let blackout = ChaosRegime {
        delay: 1.0,
        delay_secs: 10.0,
        ..ChaosRegime::default()
    };
    let plan =
        ChaosPlan::seeded(0xB1A0).with_rule(NodeSelector::EveryKth { k: 4, offset: 1 }, blackout);
    let clean = run_fleet_with_path(&specs, &mut FrozenLimits, &cfg, SimPath::Batched);
    let dark = run_fleet_with_chaos(
        &specs,
        &mut FrozenLimits,
        &cfg,
        SimPath::Batched,
        &FaultPlan::default(),
        &plan,
    );
    for i in 0..n {
        let r = &dark.records[i];
        if i % 4 == 1 {
            assert!(r.completed, "blacked-out node {i} did not complete");
            for kind in [
                FaultEventKind::WatchdogStale,
                FaultEventKind::FallbackFullCap,
                FaultEventKind::Reengage,
                FaultEventKind::ChaosDelay,
            ] {
                assert!(
                    r.faults.iter().any(|e| e.kind == kind),
                    "node {i} missing {kind:?} — ladder not fully walked"
                );
            }
            // The ladder order is causal: staleness precedes the fallback,
            // the fallback precedes the re-engage.
            let first = |k: FaultEventKind| {
                r.faults
                    .iter()
                    .find(|e| e.kind == k)
                    .map(|e| e.t)
                    .unwrap()
            };
            let stale = first(FaultEventKind::WatchdogStale);
            let fallback = first(FaultEventKind::FallbackFullCap);
            let reengage = first(FaultEventKind::Reengage);
            assert!(
                stale <= fallback && fallback < reengage,
                "node {i}: ladder out of order ({stale} / {fallback} / {reengage})"
            );
        } else {
            assert_eq!(
                clean.records[i].to_json().dump(),
                r.to_json().dump(),
                "node {i}'s bytes perturbed by its neighbours' blackout"
            );
        }
    }
}

/// Retry backoff is seed-deterministic and deadline-capped: two actuators
/// under the same `(policy, seed)` sleep the exact same schedule, a
/// different seed (generically) differs, and cumulative backoff never
/// exceeds the policy deadline — the cap that keeps a wedged actuator from
/// stalling the control period indefinitely.
#[test]
fn retry_backoff_is_deterministic_and_deadline_capped() {
    let policy = RetryPolicy {
        max_attempts: 10,
        base_delay: 0.2,
        factor: 2.0,
        max_delay: 5.0,
        deadline: 1.5,
        jitter: 0.5,
    };
    let run = |seed: u64| {
        let slept = Arc::new(Mutex::new(Vec::new()));
        let recorder = Arc::clone(&slept);
        let mut act = RetryingActuator::new(
            |_w: f64| -> powerctl::util::error::Result<f64> {
                Err(powerctl::err!("actuator wedged"))
            },
            policy,
            seed,
        )
        .with_sleeper(move |d| recorder.lock().unwrap().push(d));
        let err = act.apply(60.0).unwrap_err().to_string();
        assert!(err.contains("pcap actuation"), "{err}");
        assert!(err.contains("actuator wedged"), "{err}");
        assert!(act.give_ups() == 1 && act.attempts() >= 2);
        let schedule = slept.lock().unwrap().clone();
        schedule
    };
    let a = run(42);
    let b = run(42);
    let c = run(43);
    assert_eq!(a, b, "same seed must sleep the same backoff schedule");
    assert_ne!(a, c, "different seed must (generically) differ");
    let total: f64 = a.iter().sum();
    assert!(
        total <= policy.deadline + 1e-12,
        "slept {total} s > {} s deadline cap",
        policy.deadline
    );
    assert!(!a.is_empty(), "a wedged actuator must have backed off");
}
