//! `cargo bench --bench figures` — one end-to-end bench per paper table and
//! figure (deliverable d): each bench regenerates the artifact at Fast
//! scale and prints the paper-shape summary rows alongside its timing, so
//! a single `cargo bench` run both re-derives every result and reports the
//! cost of doing so.

use powerctl::experiments::{self, Ctx, Scale};
use powerctl::util::bench::{black_box, section, Bench};

fn ctx() -> Ctx {
    let dir = std::env::temp_dir().join("powerctl-bench-figs");
    Ctx::new(dir, 42, Scale::Fast)
}

fn main() {
    let ctx = ctx();
    std::fs::create_dir_all(&ctx.out_dir).ok();
    let bench = Bench::endtoend();

    section("Table 1 — cluster characteristics");
    let mut t1 = String::new();
    bench.run("table1", || {
        t1 = experiments::tables::table1();
        black_box(&t1);
    });
    print!("{t1}");

    section("Table 2 — identification campaign (static + dynamic fit)");
    let mut idents = Vec::new();
    let mut t2 = String::new();
    bench.run("table2_identify_all", || {
        let (out, ids) = experiments::tables::run(&ctx);
        t2 = out;
        idents = ids;
        black_box(&idents);
    });
    print!("{t2}");

    section("Fig. 3 — staircase characterization");
    let mut f3 = String::new();
    bench.run("fig3_staircase_all_clusters", || {
        let (out, s) = experiments::fig3::run(&ctx);
        f3 = out;
        black_box(s);
    });
    print!("{f3}");

    section("Fig. 4 — static characteristic");
    let mut f4 = String::new();
    bench.run("fig4_static_fit", || {
        let (out, s) = experiments::fig4::run(&ctx, &idents);
        f4 = out;
        black_box(s);
    });
    print!("{f4}");

    section("Fig. 5 — dynamic model validation");
    let mut f5 = String::new();
    bench.run("fig5_dynamic_validation", || {
        let (out, s) = experiments::fig5::run(&ctx, &idents);
        f5 = out;
        black_box(s);
    });
    print!("{f5}");

    section("Fig. 6 — closed-loop evaluation");
    let mut f6 = String::new();
    bench.run("fig6_tracking", || {
        let (out, s) = experiments::fig6::run(&ctx, &idents);
        f6 = out;
        black_box(s);
    });
    print!("{f6}");

    section("Fig. 7 — time/energy Pareto sweep");
    let mut f7 = String::new();
    bench.run("fig7_pareto_sweep", || {
        let (out, s) = experiments::fig7::run(&ctx, &idents);
        f7 = out;
        black_box(s);
    });
    print!("{f7}");

    section("Ablations");
    let mut ab = String::new();
    bench.run("ablations", || {
        ab = experiments::ablation::run(&ctx, &idents);
        black_box(&ab);
    });
    print!("{ab}");
}
