//! `cargo bench --bench l3_hotpath` — L3 hot-path micro-benchmarks
//! (§Perf targets, DESIGN.md §7):
//!
//! * controller step: target ≪ 1 ms (sampling period is 1 s);
//! * Eq. (1) heartbeat ingestion + median: target ≥ 1 M beats/s;
//! * simulated node step: dominates campaign wall-time;
//! * one full closed-loop run (the fig7 unit of work);
//! * one fleet control period (16 engines + budget allocation, in-process);
//! * **fleet executor scaling**: node-ticks/s of the sharded executor at
//!   16/256/1024 nodes vs the legacy one-thread-per-node protocol, plus a
//!   steady-state allocation check (the tick path must not allocate);
//! * **SIMD vs scalar stepping**: `fleet_simd_*` (lane-exact `F64x4`
//!   sub-steps, the default) against the scalar-resident oracle and the
//!   classic loops, with byte-identity asserted first
//!   (`simd_vs_scalar_identical`), plus per-component OU/plant/RAPL
//!   microbenches and a one-line NUMA pin-status notice;
//! * **fault plane**: `fleet_faulty_node_ticks_per_s_256` — the same
//!   resident path under the 10% sensor-dropout regime — reported only
//!   after the empty-plan byte-identity contract is asserted in-bench
//!   (`faults_empty_plan_identical`, grepped by the CI gate), and the
//!   steady-state allocation check extended over the fault-check branch
//!   of the no-fault hot path;
//! * **coordinator tree**: `fleet_tree_node_ticks_per_s_*` — the same
//!   drive shape with a depth-3 hierarchical `CoordinatorTree` at the
//!   budget layer — reported only after the depth-1-vs-flat and
//!   parallel-vs-serial byte-identity contracts are asserted in-bench
//!   (`tree_vs_flat_identical`, grepped by the CI gate), plus a
//!   counting-allocator window over full tree-mode control periods
//!   (epoch allocation at every level included);
//! * **checkpoint/restore**: `fleet_checkpoint_overhead_pct_256` — wall
//!   overhead of a crash-consistent snapshot every 32 periods on the
//!   256-node drive — reported only after the kill/resume byte-identity
//!   contract is asserted in-bench under an active fault plan
//!   (`restore_vs_uninterrupted_identical`, grepped by the CI gate);
//! * **chaos plane**: `fleet_chaos_node_ticks_per_s_256` — the same
//!   resident drive under the 10% loss + 10% dup + 50% reorder transport
//!   storm with the per-node watchdog armed — reported only after the
//!   empty-plan byte-identity contract is asserted in-bench
//!   (`chaos_empty_plan_identical`, grepped by the CI gate), plus the
//!   per-retry backoff decision (`retry_backoff_decide_ns`) and a
//!   zero-allocation window over the armed watchdog/deadline-scheduler
//!   branch.
//!
//! Emits the machine-readable `BENCH_l3.json` (override the path with
//! `BENCH_L3_JSON`). `POWERCTL_BENCH_SMOKE=1` caps iterations and fleet
//! sizes for the CI smoke run.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use powerctl::control::baseline::{PiPolicy, Uncontrolled};
use powerctl::control::budget::{BudgetPolicy, NodeReport, SlackProportional};
use powerctl::control::pi::{PiConfig, PiController};
use powerctl::coordinator::chaos::ChaosPlan;
use powerctl::coordinator::engine::{CatchUp, ControlLoop, LockstepBackend, PeriodScheduler};
use powerctl::coordinator::experiment::{run_closed_loop, RunConfig};
use powerctl::coordinator::progress::ProgressAggregator;
use powerctl::coordinator::supervisor::Watchdog;
use powerctl::experiments::{identify, Ctx, Scale};
use powerctl::control::node_budget::{ideal_device_model, DeviceCtl, DeviceSplitSpec, NodeBudgetController};
use powerctl::control::tree::{BudgetPolicySpec, CoordinatorTree, TreeSpec};
use powerctl::coordinator::hetero::HeteroBackend;
use powerctl::fleet::coordinator::node_seed;
use powerctl::fleet::{
    resume_fleet, run_fleet, run_fleet_killed, run_fleet_threaded, run_fleet_tree_with_path,
    run_fleet_with_chaos, run_fleet_with_checkpoints, run_fleet_with_faults, run_fleet_with_path,
    BudgetedPolicy, CheckpointSpec, FleetConfig, NodeHardware, NodePolicySpec, NodeSpec,
    ShardedExecutor, SimPath, WorkerConfig,
};
use powerctl::sim::device::DeviceSpec;
use powerctl::sim::faults::{FaultPlan, FaultRegime, NodeSelector};
use powerctl::sim::cluster::{Cluster, ClusterId};
use powerctl::sim::node::NodeSim;
use powerctl::util::bench::{black_box, section, smoke, Bench, Report};
use powerctl::util::parallel::{default_threads, PinStatus};
use powerctl::util::retry::{Retrier, RetryPolicy};

/// Counting allocator: lets the bench prove the steady-state fleet tick
/// path performs zero allocations (counts every alloc/realloc on every
/// thread, including the pool workers).
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

fn gros_specs(ident: &powerctl::experiments::Identified, n: usize, epsilon: f64) -> Vec<NodeSpec> {
    (0..n)
        .map(|_| NodeSpec {
            cluster: ClusterId::Gros,
            model: ident.model.clone(),
            policy: NodePolicySpec::Pi { epsilon },
            hardware: NodeHardware::SingleCpu,
        })
        .collect()
}

fn main() {
    let ctx = Ctx::new(std::env::temp_dir().join("powerctl-bench-l3"), 42, Scale::Fast);
    std::fs::create_dir_all(&ctx.out_dir).ok();
    let ident = identify(&ctx, ClusterId::Gros);
    let cluster = Cluster::get(ClusterId::Gros);
    let fast = Bench::scaled();
    let mut report = Report::new();

    section("controller");
    {
        let cfg = PiConfig::from_model(&ident.model, 10.0, 40.0, 120.0);
        let mut ctl = PiController::new(ident.model.clone(), cfg, 0.15);
        let mut t = 0.0;
        let r = fast.run("pi_controller_step", || {
            t += 1.0;
            black_box(ctl.step(t, 21.0 + (t % 3.0)));
        });
        // Timing asserts are advisory under CI smoke (shared runners are
        // too noisy for hard wall-clock gates on 100 ms windows).
        assert!(
            smoke() || r.mean < std::time::Duration::from_millis(1),
            "PI step must be ≪ 1 ms"
        );
        report.add(&r);
    }

    section("progress aggregation (Eq. 1)");
    {
        // 1000 beats per window at ~25 Hz equivalent spacing.
        let mut agg = ProgressAggregator::new();
        let mut beats = Vec::with_capacity(1000);
        let mut base = 0.0;
        let r = fast.run("ingest_1000_beats_plus_median", || {
            beats.clear();
            for i in 0..1000 {
                beats.push(base + i as f64 * 0.04);
            }
            base += 40.0;
            agg.ingest(&beats);
            black_box(agg.sample());
        });
        let beats_per_sec = 1000.0 * r.ops_per_sec();
        println!("  → {:.2}M beats/s ingested+aggregated", beats_per_sec / 1e6);
        assert!(
            smoke() || beats_per_sec > 1e6,
            "Eq. 1 path below 1M beats/s"
        );
        report.add(&r);
        report.add_metric("eq1_beats_per_sec", beats_per_sec);
    }

    section("simulated node");
    {
        let mut node = NodeSim::new(cluster.clone(), 7);
        node.set_pcap(100.0);
        let mut beats = Vec::new();
        let r = fast.run("node_step_into_1s_(20_substeps)", || {
            beats.clear();
            black_box(node.step_into(1.0, &mut beats));
        });
        report.add(&r);
        // Classic scalar baseline for the same 20-sub-step period.
        let mut classic = NodeSim::new(cluster.clone(), 7);
        classic.set_classic_stepping(true);
        classic.set_pcap(100.0);
        let rc = fast.run("node_step_into_1s_classic_stepping", || {
            beats.clear();
            black_box(classic.step_into(1.0, &mut beats));
        });
        report.add(&rc);
        // Steady-state kernel tick must be allocation-free: the bench loop
        // above drove every buffer (beat sink, SoA arrays, consts memo) to
        // its high-water capacity.
        let counted = if smoke() { 200u64 } else { 2_000 };
        let before = allocations();
        for _ in 0..counted {
            beats.clear();
            black_box(node.step_into(1.0, &mut beats));
        }
        let delta = allocations() - before;
        println!("  allocations over {counted} steady-state kernel node steps: {delta}");
        report.add_metric("node_kernel_steady_state_allocations", delta as f64);
        assert_eq!(delta, 0, "kernel node tick allocated {delta} times");
    }

    section("end-to-end closed-loop runs");
    {
        let slow = Bench::endtoend();
        let cfg = RunConfig {
            sample_period: 1.0,
            total_beats: 1_500,
            max_time: 600.0,
        };
        let mut seed = 0u64;
        let r = slow.run("uncontrolled_run_1500_beats", || {
            seed += 1;
            let mut p = Uncontrolled { pcap_max: 120.0 };
            black_box(run_closed_loop(&cluster, &mut p, f64::NAN, 0.0, &cfg, seed));
        });
        report.add(&r);
        let r = slow.run("pi_run_1500_beats_eps0.15", || {
            seed += 1;
            let pic = PiConfig::from_model(&ident.model, 10.0, 40.0, 120.0);
            let ctl = PiController::new(ident.model.clone(), pic, 0.15);
            let sp = ctl.setpoint();
            let mut p = PiPolicy(ctl);
            black_box(run_closed_loop(&cluster, &mut p, sp, 0.15, &cfg, seed));
        });
        report.add(&r);
    }

    section("fleet control period (16 nodes, in-process)");
    {
        // One fleet period = 16 engine ticks (node step + Eq. 1 + PI) plus
        // one budget allocation — the unit of work the fleet coordinator
        // repeats every simulated second. Engines run in-process here so
        // the number excludes all executor overhead.
        const NODES: usize = 16;
        let spec = NodeSpec {
            cluster: ClusterId::Gros,
            model: ident.model.clone(),
            policy: NodePolicySpec::Pi { epsilon: 0.15 },
            hardware: NodeHardware::SingleCpu,
        };
        let share = 95.0;
        let mut engines: Vec<(ControlLoop<LockstepBackend>, BudgetedPolicy)> = (0..NODES)
            .map(|i| {
                let policy = BudgetedPolicy::new(&spec, &cluster, share);
                let node = NodeSim::new(cluster.clone(), 1000 + i as u64);
                let mut engine = ControlLoop::new(LockstepBackend::new(node), 1.0);
                engine.set_initial_pcap(policy.initial_pcap());
                (engine, policy)
            })
            .collect();
        let mut strategy = SlackProportional::default();
        let mut reports: Vec<NodeReport> = Vec::with_capacity(NODES);
        let mut limits = vec![0.0; NODES];
        let mut now = 0.0;
        // Cap iterations: every period appends one record row per engine.
        let capped = Bench {
            max_iterations: if smoke() { 500 } else { 20_000 },
            ..Bench::scaled()
        };
        let r = capped.run("fleet_period_16_nodes_tick_plus_alloc", || {
            now += 1.0;
            reports.clear();
            for (i, (engine, policy)) in engines.iter_mut().enumerate() {
                let s = engine.tick(now, policy);
                reports.push(NodeReport {
                    node_id: i as u32,
                    limit: policy.limit(),
                    pcap: s.pcap,
                    power: s.power,
                    progress: s.progress,
                    setpoint: policy.setpoint(),
                    pcap_min: cluster.pcap_min,
                    pcap_max: cluster.pcap_max,
                    done: false,
                    failed: false,
                });
            }
            strategy.allocate_into(now, share * NODES as f64, &reports, &mut limits);
            for ((_, policy), &l) in engines.iter_mut().zip(&limits) {
                policy.set_limit(l);
            }
            black_box(&limits);
        });
        report.add(&r);
    }

    section("fleet executor scaling (sharded vs per-node threads)");
    {
        // Throughput (node-ticks/s) of the sharded executor across fleet
        // sizes, and the speedup over the legacy one-thread-per-node mpsc
        // protocol at the acceptance size. `total_beats` is unreachable so
        // every node runs the full horizon; `max_time` bounds the periods.
        // Smoke keeps the documented `_256` sharded key in the artifact
        // (sharded 256 nodes × few periods is cheap); only the legacy
        // baseline shrinks, since 256 OS threads on a small CI runner is
        // the expensive part — hence the speedup key is `_64` under smoke.
        let sizes: &[usize] = if smoke() { &[16, 64, 256] } else { &[16, 256, 1024] };
        let baseline_nodes = if smoke() { 64 } else { 256 };
        let drive = |n: usize, periods: f64, threaded: bool| -> (f64, u64) {
            let cfg = FleetConfig {
                budget: 95.0 * n as f64,
                period: 1.0,
                realloc_every: 5,
                total_beats: u64::MAX,
                max_time: periods,
                seed: 42,
                threads: None,
            };
            let specs = gros_specs(&ident, n, 0.15);
            let mut strategy = SlackProportional::default();
            let out = if threaded {
                run_fleet_threaded(&specs, &mut strategy, &cfg)
            } else {
                run_fleet(&specs, &mut strategy, &cfg)
            };
            (out.node_ticks as f64 / out.wall_seconds, out.node_ticks)
        };

        let mut sharded_at_baseline = f64::NAN;
        for &n in sizes {
            let periods = if smoke() { 20.0 } else { 120.0 };
            let (tps, ticks) = drive(n, periods, false);
            println!("  sharded  {n:>5} nodes: {tps:>12.0} node-ticks/s ({ticks} ticks)");
            report.add_metric(&format!("fleet_sharded_node_ticks_per_s_{n}"), tps);
            if n == baseline_nodes {
                sharded_at_baseline = tps;
            }
        }
        let periods = if smoke() { 10.0 } else { 40.0 };
        let (tps_threaded, ticks) = drive(baseline_nodes, periods, true);
        println!(
            "  threaded {baseline_nodes:>5} nodes: {tps_threaded:>12.0} node-ticks/s ({ticks} ticks, legacy mpsc)"
        );
        report.add_metric(
            &format!("fleet_threaded_node_ticks_per_s_{baseline_nodes}"),
            tps_threaded,
        );
        let speedup = sharded_at_baseline / tps_threaded;
        println!("  → sharded executor speedup at {baseline_nodes} nodes: {speedup:.1}×");
        report.add_metric(&format!("fleet_sharded_speedup_{baseline_nodes}"), speedup);
    }

    section("resident kernel vs classic stepping (node-ticks/s)");
    {
        // The tentpole numbers: fleet throughput with the resident
        // shard-major SoA kernel — lane-exact SIMD sub-steps by default
        // (`fleet_simd_*`), the scalar-resident oracle (`fleet_kernel_*` /
        // `fleet_resident_*`, keeping their PR 4/5 key names so the
        // trajectory tables stay comparable) and the classic per-node
        // loops (`fleet_classic_*`) — all on the SAME sharded executor,
        // isolating the stepping path from the execution mechanism. All
        // three paths produce identical record bytes by construction;
        // asserted below before any throughput is reported, and the CI
        // gate greps BENCH_l3.json for both equivalence metrics so the
        // case cannot silently be skipped.
        let drive = |n: usize, periods: f64, path: SimPath| -> (f64, u64) {
            let cfg = FleetConfig {
                budget: 95.0 * n as f64,
                period: 1.0,
                realloc_every: 5,
                total_beats: u64::MAX,
                max_time: periods,
                seed: 42,
                threads: None,
            };
            let specs = gros_specs(&ident, n, 0.15);
            let mut strategy = SlackProportional::default();
            let out = run_fleet_with_path(&specs, &mut strategy, &cfg, path);
            (out.node_ticks as f64 / out.wall_seconds, out.node_ticks)
        };

        // Equivalence case first: a mixed fleet (classic single-CPU nodes
        // plus a hierarchical CPU+GPU node) under a tight budget, compared
        // byte-for-byte across all three stepping paths.
        {
            let mut specs = gros_specs(&ident, 5, 0.15);
            specs.push(NodeSpec {
                cluster: ClusterId::Gros,
                model: ident.model.clone(),
                policy: NodePolicySpec::Static,
                hardware: NodeHardware::cpu_gpu(&cluster, DeviceSplitSpec::SlackShift, 0.15),
            });
            let cfg = FleetConfig {
                budget: 90.0 * 5.0 + 360.0,
                period: 1.0,
                realloc_every: 5,
                total_beats: 400,
                max_time: 60.0,
                seed: 7,
                threads: None,
            };
            let to_bytes = |out: &powerctl::fleet::FleetOutcome| {
                out.records
                    .iter()
                    .map(|r| r.to_json().dump())
                    .collect::<Vec<_>>()
                    .join("\n")
            };
            let batched = run_fleet_with_path(
                &specs,
                &mut SlackProportional::default(),
                &cfg,
                SimPath::Batched,
            );
            let scalar = run_fleet_with_path(
                &specs,
                &mut SlackProportional::default(),
                &cfg,
                SimPath::BatchedScalar,
            );
            let classic = run_fleet_with_path(
                &specs,
                &mut SlackProportional::default(),
                &cfg,
                SimPath::Classic,
            );
            assert_eq!(
                to_bytes(&scalar),
                to_bytes(&classic),
                "kernel records diverge from classic records"
            );
            assert_eq!(
                to_bytes(&batched),
                to_bytes(&scalar),
                "SIMD records diverge from scalar-resident records"
            );
            println!(
                "  kernel-vs-classic + simd-vs-scalar equivalence: byte-identical on a 6-node mixed fleet"
            );
            report.add_metric("kernel_vs_classic_identical", 1.0);
            report.add_metric("simd_vs_scalar_identical", 1.0);
        }

        let sizes: &[usize] = if smoke() { &[16, 64, 256] } else { &[16, 256, 1024] };
        for &n in sizes {
            let periods = if smoke() { 20.0 } else { 120.0 };
            let (simd_tps, ticks) = drive(n, periods, SimPath::Batched);
            let (kernel_tps, _) = drive(n, periods, SimPath::BatchedScalar);
            let (classic_tps, _) = drive(n, periods, SimPath::Classic);
            println!(
                "  {n:>5} nodes: simd {simd_tps:>12.0} | scalar-resident {kernel_tps:>12.0} | classic {classic_tps:>12.0} node-ticks/s | simd/scalar {:.2}× ({ticks} ticks)",
                simd_tps / kernel_tps
            );
            report.add_metric(&format!("fleet_simd_node_ticks_per_s_{n}"), simd_tps);
            report.add_metric(&format!("fleet_kernel_node_ticks_per_s_{n}"), kernel_tps);
            report.add_metric(&format!("fleet_resident_node_ticks_per_s_{n}"), kernel_tps);
            report.add_metric(&format!("fleet_classic_node_ticks_per_s_{n}"), classic_tps);
            report.add_metric(
                &format!("fleet_kernel_speedup_{n}"),
                kernel_tps / classic_tps,
            );
            report.add_metric(&format!("fleet_simd_speedup_{n}"), simd_tps / kernel_tps);
        }
    }

    section("fault plane (empty-plan identity + 10% sensor-dropout regime)");
    {
        // Contract first, throughput second. The empty-plan identity is
        // asserted here — in the same binary that reports the faulty
        // throughput — so the `faults_empty_plan_identical` metric the CI
        // gate greps for cannot appear without the byte-equality having
        // actually held on this build.
        let to_bytes = |out: &powerctl::fleet::FleetOutcome| {
            out.records
                .iter()
                .map(|r| r.to_json().dump())
                .collect::<Vec<_>>()
                .join("\n")
        };
        {
            let specs = gros_specs(&ident, 8, 0.15);
            let cfg = FleetConfig {
                budget: 85.0 * 8.0,
                period: 1.0,
                realloc_every: 5,
                total_beats: 400,
                max_time: 60.0,
                seed: 11,
                threads: None,
            };
            let clean = run_fleet_with_path(
                &specs,
                &mut SlackProportional::default(),
                &cfg,
                SimPath::Batched,
            );
            let empty = run_fleet_with_faults(
                &specs,
                &mut SlackProportional::default(),
                &cfg,
                SimPath::Batched,
                &FaultPlan::default(),
            );
            assert_eq!(
                to_bytes(&clean),
                to_bytes(&empty),
                "empty fault plan perturbed record bytes"
            );
            assert_eq!(
                clean.limits_trace, empty.limits_trace,
                "empty fault plan perturbed the ceiling trace"
            );
            println!("  empty-plan identity: byte-identical on an 8-node fleet");
            report.add_metric("faults_empty_plan_identical", 1.0);
        }

        // Throughput under the documented degradation regime: fleet-wide
        // 10% sensor dropout (telemetry faults only — every node keeps
        // running, the PI freshness gate does the extra work). Same drive
        // shape as the clean `fleet_simd_node_ticks_per_s_256` key so the
        // two are directly comparable.
        let n = 256;
        let periods = if smoke() { 20.0 } else { 120.0 };
        let cfg = FleetConfig {
            budget: 95.0 * n as f64,
            period: 1.0,
            realloc_every: 5,
            total_beats: u64::MAX,
            max_time: periods,
            seed: 42,
            threads: None,
        };
        let specs = gros_specs(&ident, n, 0.15);
        let plan = FaultPlan::seeded(42).with_rule(
            NodeSelector::All,
            FaultRegime {
                sensor_dropout: 0.10,
                ..FaultRegime::default()
            },
        );
        let mut strategy = SlackProportional::default();
        let out = run_fleet_with_faults(&specs, &mut strategy, &cfg, SimPath::Batched, &plan);
        let tps = out.node_ticks as f64 / out.wall_seconds;
        println!(
            "  faulty   {n:>5} nodes: {tps:>12.0} node-ticks/s ({} ticks, 10% sensor dropout)",
            out.node_ticks
        );
        report.add_metric(&format!("fleet_faulty_node_ticks_per_s_{n}"), tps);
    }

    section("coordinator tree (depth-1 identity + hierarchical epoch throughput)");
    {
        // Contract first, throughput second — same shape as the fault
        // section. Two identities are asserted in the same binary that
        // reports the tree throughput, so the `tree_vs_flat_identical`
        // metric the CI gate greps for cannot appear without both having
        // held on this build:
        //  (1) the depth-1 tree is byte-identical to the flat budget
        //      path (records AND ceiling trace);
        //  (2) a depth-3 tree on an all-core pool (parallel sub-tree
        //      passes) is byte-identical to the same tree on a forced
        //      single-thread pool (serial allocation).
        let to_bytes = |out: &powerctl::fleet::FleetOutcome| {
            out.records
                .iter()
                .map(|r| r.to_json().dump())
                .collect::<Vec<_>>()
                .join("\n")
        };
        {
            let specs = gros_specs(&ident, 8, 0.15);
            let cfg = FleetConfig {
                budget: 85.0 * 8.0,
                period: 1.0,
                realloc_every: 5,
                total_beats: 400,
                max_time: 60.0,
                seed: 11,
                threads: None,
            };
            let flat = run_fleet_with_path(
                &specs,
                &mut SlackProportional::default(),
                &cfg,
                SimPath::Batched,
            );
            let mut d1 = CoordinatorTree::new(&TreeSpec::flat(
                BudgetPolicySpec::SlackProportional,
                specs.len(),
            ));
            let depth1 = run_fleet_tree_with_path(&specs, &mut d1, &cfg, SimPath::Batched);
            assert_eq!(
                to_bytes(&flat),
                to_bytes(&depth1),
                "depth-1 tree records diverge from the flat budget path"
            );
            assert_eq!(
                flat.limits_trace, depth1.limits_trace,
                "depth-1 tree ceiling trace diverges from the flat budget path"
            );

            let d3_spec =
                TreeSpec::balanced(BudgetPolicySpec::SlackProportional, 3, 2, specs.len());
            let mut d3_par = CoordinatorTree::new(&d3_spec);
            let parallel = run_fleet_tree_with_path(&specs, &mut d3_par, &cfg, SimPath::Batched);
            let serial_cfg = FleetConfig {
                threads: Some(1),
                ..cfg.clone()
            };
            let mut d3_ser = CoordinatorTree::new(&d3_spec);
            let serial =
                run_fleet_tree_with_path(&specs, &mut d3_ser, &serial_cfg, SimPath::Batched);
            assert_eq!(
                to_bytes(&parallel),
                to_bytes(&serial),
                "parallel sub-tree passes diverge from serial tree allocation"
            );
            assert_eq!(
                parallel.limits_trace, serial.limits_trace,
                "parallel vs serial tree ceiling traces diverge"
            );
            println!(
                "  tree-vs-flat + parallel-vs-serial equivalence: byte-identical on an 8-node fleet"
            );
            report.add_metric("tree_vs_flat_identical", 1.0);
        }

        // Throughput with a depth-3, arity-8 tree at the budget layer —
        // same drive shape as the flat `fleet_simd_*` keys, so the cost
        // of hierarchical epochs is directly comparable.
        let sizes: &[usize] = if smoke() { &[16, 64, 256] } else { &[16, 256, 1024] };
        for &n in sizes {
            let periods = if smoke() { 20.0 } else { 120.0 };
            let cfg = FleetConfig {
                budget: 95.0 * n as f64,
                period: 1.0,
                realloc_every: 5,
                total_beats: u64::MAX,
                max_time: periods,
                seed: 42,
                threads: None,
            };
            let specs = gros_specs(&ident, n, 0.15);
            let mut tree = CoordinatorTree::new(&TreeSpec::balanced(
                BudgetPolicySpec::SlackProportional,
                3,
                8,
                n,
            ));
            let out = run_fleet_tree_with_path(&specs, &mut tree, &cfg, SimPath::Batched);
            let tps = out.node_ticks as f64 / out.wall_seconds;
            println!(
                "  tree     {n:>5} nodes: {tps:>12.0} node-ticks/s ({} ticks, depth 3, {} interiors, max {} children)",
                out.node_ticks,
                tree.interiors().len(),
                tree.max_children()
            );
            report.add_metric(&format!("fleet_tree_node_ticks_per_s_{n}"), tps);
        }

        // Zero-allocation window over FULL tree-mode control periods:
        // tick, the hierarchical epoch (upward aggregation, root
        // allocation, downward re-apportioning at every level — via the
        // executor's parallel sub-tree passes) and ceiling application.
        // Tree construction and rebalance migrations may allocate; the
        // steady state must not (grant trace off — recording clones per
        // epoch by design).
        let n = if smoke() { 32 } else { 256 };
        let (warm, measured) = (50u64, 25u64);
        let cfg = WorkerConfig {
            period: 1.0,
            total_beats: u64::MAX,
            max_time: (warm + measured + 8) as f64,
        };
        let specs = gros_specs(&ident, n, 0.15);
        let seeds: Vec<u64> = (0..n).map(|i| node_seed(42, i)).collect();
        let threads = default_threads().min(n);
        let mut exec = ShardedExecutor::new(&specs, 95.0, cfg, &seeds, threads);
        let mut tree = CoordinatorTree::new(&TreeSpec::balanced(
            BudgetPolicySpec::SlackProportional,
            3,
            8,
            n,
        ));
        let budget = 95.0 * n as f64;
        let mut limits = vec![0.0; n];
        let mut now = 0.0;
        let mut epoch = |exec: &mut ShardedExecutor, tree: &mut CoordinatorTree| {
            now += 1.0;
            exec.tick(now);
            exec.allocate_tree(tree, now, budget, &mut limits);
            exec.set_limits(&limits);
        };
        for _ in 1..=warm {
            epoch(&mut exec, &mut tree);
        }
        exec.set_rebalance_every(0);
        let before = allocations();
        for _ in warm + 1..=warm + measured {
            epoch(&mut exec, &mut tree);
        }
        let delta = allocations() - before;
        println!(
            "  allocations over {measured} steady-state tree-mode periods × {n} nodes \
             (tick + epoch allocation at every level + ceiling application): {delta}"
        );
        report.add_metric("fleet_tree_steady_state_allocations", delta as f64);
        assert_eq!(
            delta, 0,
            "steady-state tree-mode control period allocated {delta} times"
        );
    }

    section("checkpoint/restore (kill-resume identity + snapshot overhead)");
    {
        // Contract first, overhead second — same shape as the fault and
        // tree sections. The kill/resume byte-identity is asserted here,
        // in the same binary that reports the checkpoint overhead, so the
        // `restore_vs_uninterrupted_identical` metric the CI gate greps
        // for cannot appear without the identity having held on this
        // build. The scenario is deliberately hostile: an ACTIVE
        // crash/restart fault plan, a kill off the reallocation-epoch
        // boundary, and the resumed run compared byte-for-byte (records
        // AND ceiling trace) against the uninterrupted oracle.
        let to_bytes = |out: &powerctl::fleet::FleetOutcome| {
            out.records
                .iter()
                .map(|r| r.to_json().dump())
                .collect::<Vec<_>>()
                .join("\n")
        };
        {
            let specs = gros_specs(&ident, 8, 0.15);
            let cfg = FleetConfig {
                budget: 85.0 * 8.0,
                period: 1.0,
                realloc_every: 5,
                total_beats: 400,
                max_time: 60.0,
                seed: 11,
                threads: None,
            };
            let plan = FaultPlan::seeded(0x5EED).with_rule(
                NodeSelector::Node(2),
                FaultRegime {
                    crash_at: Some(12.0),
                    restart_after: Some(15.0),
                    ..FaultRegime::default()
                },
            );
            let oracle = run_fleet_with_faults(
                &specs,
                &mut SlackProportional::default(),
                &cfg,
                SimPath::Batched,
                &plan,
            );
            let ckpt = CheckpointSpec {
                every: 1,
                path: ctx.path("bench_ckpt.bin"),
            };
            let killed = run_fleet_killed(
                &specs,
                &mut SlackProportional::default(),
                &cfg,
                SimPath::Batched,
                &plan,
                &ckpt,
                17,
            )
            .expect("checkpointed drive failed");
            assert!(killed.is_none(), "kill at period 17 did not fire");
            let resumed = resume_fleet(
                &specs,
                &mut SlackProportional::default(),
                &cfg,
                SimPath::Batched,
                &plan,
                &ckpt.path,
            )
            .expect("resume failed");
            assert_eq!(
                to_bytes(&oracle),
                to_bytes(&resumed),
                "resumed records diverge from the uninterrupted run"
            );
            assert_eq!(
                oracle.limits_trace, resumed.limits_trace,
                "resumed ceiling trace diverges from the uninterrupted run"
            );
            println!(
                "  kill@17 + resume under an active crash/restart plan: byte-identical on an 8-node fleet"
            );
            report.add_metric("restore_vs_uninterrupted_identical", 1.0);
            let _ = std::fs::remove_file(&ckpt.path);
        }

        // Overhead of periodic snapshots on the acceptance-size fleet:
        // the same 256-node drive with and without a checkpoint every 32
        // periods (serialize + CRC + atomic tmp/fsync/rename each time).
        let n = 256;
        let periods = if smoke() { 20.0 } else { 120.0 };
        let cfg = FleetConfig {
            budget: 95.0 * n as f64,
            period: 1.0,
            realloc_every: 5,
            total_beats: u64::MAX,
            max_time: periods,
            seed: 42,
            threads: None,
        };
        let specs = gros_specs(&ident, n, 0.15);
        let plain = run_fleet_with_path(
            &specs,
            &mut SlackProportional::default(),
            &cfg,
            SimPath::Batched,
        );
        let ckpt = CheckpointSpec {
            every: if smoke() { 8 } else { 32 },
            path: ctx.path("bench_ckpt_256.bin"),
        };
        let with_ckpt = run_fleet_with_checkpoints(
            &specs,
            &mut SlackProportional::default(),
            &cfg,
            SimPath::Batched,
            &FaultPlan::default(),
            &ckpt,
        )
        .expect("checkpointed 256-node drive failed");
        let bytes = std::fs::metadata(&ckpt.path).map(|m| m.len()).unwrap_or(0);
        let overhead_pct = (with_ckpt.wall_seconds / plain.wall_seconds - 1.0) * 100.0;
        println!(
            "  {n:>5} nodes: snapshot every {} periods → {bytes} bytes/file, {overhead_pct:+.1}% wall overhead",
            ckpt.every
        );
        report.add_metric(&format!("fleet_checkpoint_overhead_pct_{n}"), overhead_pct);
        report.add_metric(&format!("fleet_checkpoint_bytes_{n}"), bytes as f64);
        let _ = std::fs::remove_file(&ckpt.path);
    }

    section("chaos plane (empty-plan identity + storm throughput + retry decide)");
    {
        // Contract first, throughput second — same shape as the fault,
        // tree and checkpoint sections. The empty-plan identity is
        // asserted here, in the same binary that reports the chaotic
        // throughput, so the `chaos_empty_plan_identical` metric the CI
        // gate greps for cannot appear without the byte-equality having
        // actually held on this build.
        let to_bytes = |out: &powerctl::fleet::FleetOutcome| {
            out.records
                .iter()
                .map(|r| r.to_json().dump())
                .collect::<Vec<_>>()
                .join("\n")
        };
        {
            let specs = gros_specs(&ident, 8, 0.15);
            let cfg = FleetConfig {
                budget: 85.0 * 8.0,
                period: 1.0,
                realloc_every: 5,
                total_beats: 400,
                max_time: 60.0,
                seed: 11,
                threads: None,
            };
            let clean = run_fleet_with_path(
                &specs,
                &mut SlackProportional::default(),
                &cfg,
                SimPath::Batched,
            );
            let empty = run_fleet_with_chaos(
                &specs,
                &mut SlackProportional::default(),
                &cfg,
                SimPath::Batched,
                &FaultPlan::default(),
                &ChaosPlan::default(),
            );
            assert_eq!(
                to_bytes(&clean),
                to_bytes(&empty),
                "empty chaos plan perturbed record bytes"
            );
            assert_eq!(
                clean.limits_trace, empty.limits_trace,
                "empty chaos plan perturbed the ceiling trace"
            );
            println!("  empty-plan identity: byte-identical on an 8-node fleet");
            report.add_metric("chaos_empty_plan_identical", 1.0);
        }

        // Throughput under the acceptance storm: fleet-wide 10% loss +
        // 10% duplication + 50% reordering, with the per-node watchdog
        // armed and the degradation ladder live. Same drive shape as the
        // clean `fleet_simd_node_ticks_per_s_256` key so the hardening
        // tax is directly comparable.
        let n = 256;
        let periods = if smoke() { 20.0 } else { 120.0 };
        let cfg = FleetConfig {
            budget: 95.0 * n as f64,
            period: 1.0,
            realloc_every: 5,
            total_beats: u64::MAX,
            max_time: periods,
            seed: 42,
            threads: None,
        };
        let specs = gros_specs(&ident, n, 0.15);
        let plan = ChaosPlan::seeded(42)
            .with_rule(NodeSelector::All, powerctl::experiments::chaos::storm_regime());
        let mut strategy = SlackProportional::default();
        let out = run_fleet_with_chaos(
            &specs,
            &mut strategy,
            &cfg,
            SimPath::Batched,
            &FaultPlan::default(),
            &plan,
        );
        let tps = out.node_ticks as f64 / out.wall_seconds;
        println!(
            "  chaotic  {n:>5} nodes: {tps:>12.0} node-ticks/s ({} ticks, 10% loss + 10% dup + 50% reorder)",
            out.node_ticks
        );
        report.add_metric(&format!("fleet_chaos_node_ticks_per_s_{n}"), tps);

        // The per-retry hot decision: one `powi`, one `min`, at most one
        // RNG draw. This is what every failed actuator write or runtime
        // RPC pays per backoff step.
        let mut retrier = Retrier::new(RetryPolicy::default(), 42);
        let mut k = 0u32;
        let r = fast.run("retry_backoff_decide", || {
            k = (k + 1) & 7;
            black_box(retrier.decide(k));
        });
        report.add(&r);
        report.add_metric("retry_backoff_decide_ns", r.mean.as_nanos() as f64);

        // Zero-allocation window over the armed watchdog + deadline-
        // scheduler branch: a hardened in-process engine (watchdog
        // installed, fresh beat stream, no chaos) plus a live
        // `PeriodScheduler` must not allocate in steady state — arming
        // the hardened plane may not tax a healthy loop.
        let counted: u64 = if smoke() { 200 } else { 2_000 };
        let rows = 200 + counted as usize + 64;
        let node = NodeSim::new(cluster.clone(), 9);
        let mut engine = ControlLoop::new(LockstepBackend::new(node), 1.0);
        engine.reserve_samples(rows);
        engine.set_initial_pcap(100.0);
        engine.set_watchdog(Watchdog::new(2.0));
        let mut policy = powerctl::control::baseline::StaticCap { pcap: 100.0 };
        let mut sched = PeriodScheduler::new(0.0, 1.0, CatchUp::Skip);
        let mut now = 0.0;
        for _ in 0..200 {
            now += 1.0;
            engine.tick(now, &mut policy);
            black_box(sched.completed(now));
        }
        let before = allocations();
        for _ in 0..counted {
            now += 1.0;
            engine.tick(now, &mut policy);
            black_box(sched.completed(now));
        }
        let delta = allocations() - before;
        println!(
            "  allocations over {counted} steady-state hardened periods \
             (armed watchdog + deadline scheduler, fresh stream): {delta}"
        );
        report.add_metric("hardened_steady_state_allocations", delta as f64);
        assert_eq!(
            delta, 0,
            "armed watchdog/scheduler branch allocated {delta} times in steady state"
        );
        assert_eq!(sched.overruns(), 0, "lockstep drive must never overrun");
        assert_eq!(
            engine.watchdog().map(|w| w.stale_verdicts()),
            Some(0),
            "fresh stream flagged stale"
        );
    }

    section("SIMD sub-step components (scalar vs lanes, 1024 devices)");
    {
        // Per-component microbench of the three lane-vectorized update
        // expressions, each written EXACTLY as the kernel computes it —
        // OU decay (`ou·decay + g`), plant smoothing
        // (`a·prog + (1−a)·target`) and the RAPL window
        // (`power + α·(target − power)`) — over a 1024-element SoA array,
        // scalar loop vs `F64x4` lane loop. Isolates the arithmetic win
        // from the gather/scatter and RNG costs that the fleet numbers
        // blend in.
        use powerctl::sim::simd::{F64x4, LANES};
        const N: usize = 1024;
        let mut a: Vec<f64> = (0..N).map(|i| 0.5 + (i as f64) * 1e-3).collect();
        let b: Vec<f64> = (0..N).map(|i| 0.1 + (i as f64) * 7e-4).collect();
        let micro = Bench::scaled();

        let r = micro.run("substep_ou_scalar_1024", || {
            for i in 0..N {
                a[i] = a[i] * 0.95 + b[i];
            }
            black_box(&a);
        });
        report.add(&r);
        let r = micro.run("substep_ou_lanes_1024", || {
            let decay = F64x4::splat(0.95);
            let mut i = 0;
            while i + LANES <= N {
                let v = F64x4::from_slice(&a[i..i + LANES]) * decay
                    + F64x4::from_slice(&b[i..i + LANES]);
                v.write_to(&mut a[i..i + LANES]);
                i += LANES;
            }
            black_box(&a);
        });
        report.add(&r);

        let r = micro.run("substep_plant_scalar_1024", || {
            for i in 0..N {
                a[i] = 0.8 * a[i] + (1.0 - 0.8) * b[i];
            }
            black_box(&a);
        });
        report.add(&r);
        let r = micro.run("substep_plant_lanes_1024", || {
            let aa = F64x4::splat(0.8);
            let one_minus = F64x4::splat(1.0) - aa;
            let mut i = 0;
            while i + LANES <= N {
                let v = aa * F64x4::from_slice(&a[i..i + LANES])
                    + one_minus * F64x4::from_slice(&b[i..i + LANES]);
                v.write_to(&mut a[i..i + LANES]);
                i += LANES;
            }
            black_box(&a);
        });
        report.add(&r);

        let r = micro.run("substep_rapl_scalar_1024", || {
            for i in 0..N {
                a[i] += 0.3 * (b[i] - a[i]);
            }
            black_box(&a);
        });
        report.add(&r);
        let r = micro.run("substep_rapl_lanes_1024", || {
            let alpha = F64x4::splat(0.3);
            let mut i = 0;
            while i + LANES <= N {
                let p = F64x4::from_slice(&a[i..i + LANES]);
                let v = p + alpha * (F64x4::from_slice(&b[i..i + LANES]) - p);
                v.write_to(&mut a[i..i + LANES]);
                i += LANES;
            }
            black_box(&a);
        });
        report.add(&r);
    }

    section("steady-state allocation check (full resident control period)");
    {
        // After warmup (sample logs pre-reserved, scratch buffers and
        // resident-kernel sinks at their high-water marks) the FULL
        // resident control period — fork/join over the shards, one
        // resident-kernel invocation per shard, Eq. (1), PI, report
        // writes, a budget allocation EVERY period, ceiling application
        // and the per-period record append — must allocate nothing.
        // Rebalance *migrations* regather state and allocate by design,
        // so the cadence is pinned to 0 for the counted window (warmup
        // runs with the default cadence, so decision epochs do fire).
        let n = if smoke() { 32 } else { 256 };
        let (warm, measured) = (200u64, 100u64);
        let cfg = WorkerConfig {
            period: 1.0,
            total_beats: u64::MAX,
            max_time: (warm + measured + 8) as f64,
        };
        let specs = gros_specs(&ident, n, 0.15);
        let seeds: Vec<u64> = (0..n).map(|i| node_seed(42, i)).collect();
        let threads = default_threads().min(n);
        let mut exec = ShardedExecutor::new(&specs, 95.0, cfg, &seeds, threads);
        // NUMA pin notice: printed once per bench run, never a failure —
        // pinning degrades gracefully on cpusets/containers and can be
        // disabled outright with POWERCTL_NO_PIN=1.
        match exec.pin_status() {
            PinStatus::Pinned { sockets, cores } => {
                println!("  worker pinning: {cores} cores across {sockets} socket(s)");
                report.add_metric("numa_pin_sockets", sockets as f64);
            }
            PinStatus::Disabled => {
                println!("  worker pinning: disabled via POWERCTL_NO_PIN");
                report.add_metric("numa_pin_sockets", 0.0);
            }
            PinStatus::Unsupported => {
                println!("  worker pinning: unsupported on this host (running unpinned)");
                report.add_metric("numa_pin_sockets", 0.0);
            }
        }
        let mut strategy = SlackProportional::default();
        let mut limits = vec![0.0; n];
        let budget = 95.0 * n as f64;
        let mut now = 0.0;
        let epoch = |exec: &mut ShardedExecutor,
                         strategy: &mut SlackProportional,
                         limits: &mut Vec<f64>,
                         now: &mut f64| {
            *now += 1.0;
            exec.tick(*now);
            strategy.allocate_into(*now, budget, exec.reports(), limits);
            exec.set_limits(limits);
        };
        for _ in 1..=warm {
            epoch(&mut exec, &mut strategy, &mut limits, &mut now);
        }
        exec.set_rebalance_every(0);
        let before = allocations();
        for _ in warm + 1..=warm + measured {
            epoch(&mut exec, &mut strategy, &mut limits, &mut now);
        }
        let delta = allocations() - before;
        println!(
            "  allocations over {measured} steady-state SIMD periods × {n} nodes \
             (tick + per-period budget allocate + record append): {delta}"
        );
        report.add_metric("fleet_steady_state_allocations", delta as f64);
        assert_eq!(
            delta, 0,
            "steady-state SIMD control period allocated {delta} times"
        );

        // Same check over a shorter window for the scalar-resident oracle
        // path: forcing scalar sub-steps must not reintroduce allocations
        // (the lane-range bookkeeping is shared and pre-reserved at adopt).
        let (warm_s, measured_s) = (50u64, 25u64);
        let cfg_s = WorkerConfig {
            period: 1.0,
            total_beats: u64::MAX,
            max_time: (warm_s + measured_s + 8) as f64,
        };
        let mut exec_s = ShardedExecutor::with_path(
            &specs,
            95.0,
            cfg_s,
            &seeds,
            threads,
            SimPath::BatchedScalar,
        );
        let mut now_s = 0.0;
        for _ in 1..=warm_s {
            epoch(&mut exec_s, &mut strategy, &mut limits, &mut now_s);
        }
        exec_s.set_rebalance_every(0);
        let before = allocations();
        for _ in warm_s + 1..=warm_s + measured_s {
            epoch(&mut exec_s, &mut strategy, &mut limits, &mut now_s);
        }
        let delta = allocations() - before;
        println!(
            "  allocations over {measured_s} steady-state scalar-resident periods × {n} nodes: {delta}"
        );
        report.add_metric("fleet_scalar_steady_state_allocations", delta as f64);
        assert_eq!(
            delta, 0,
            "steady-state scalar-resident control period allocated {delta} times"
        );

        // Fault-check branch on the no-fault hot path: every period now
        // begins with a per-cell `begin_period` fault poll before staging.
        // On an executor built through `with_faults` with an empty plan
        // that poll must be a zero-allocation no-op — the fault plane may
        // not tax clean fleets. (`with_path` routes through `with_faults`,
        // so the two windows above already walk this branch; this window
        // pins the contract by name.)
        let (warm_f, measured_f) = (50u64, 25u64);
        let cfg_f = WorkerConfig {
            period: 1.0,
            total_beats: u64::MAX,
            max_time: (warm_f + measured_f + 8) as f64,
        };
        let mut exec_f = ShardedExecutor::with_faults(
            &specs,
            95.0,
            cfg_f,
            &seeds,
            threads,
            SimPath::Batched,
            &FaultPlan::default(),
        );
        let mut now_f = 0.0;
        for _ in 1..=warm_f {
            epoch(&mut exec_f, &mut strategy, &mut limits, &mut now_f);
        }
        exec_f.set_rebalance_every(0);
        let before = allocations();
        for _ in warm_f + 1..=warm_f + measured_f {
            epoch(&mut exec_f, &mut strategy, &mut limits, &mut now_f);
        }
        let delta = allocations() - before;
        println!(
            "  allocations over {measured_f} steady-state periods × {n} nodes \
             with the fault-check branch live (empty plan): {delta}"
        );
        report.add_metric("fleet_fault_branch_steady_state_allocations", delta as f64);
        assert_eq!(
            delta, 0,
            "empty-plan fault-check branch allocated {delta} times in steady state"
        );
    }

    section("hierarchical node tick (CPU+GPU device loop)");
    {
        // One hierarchical control period = device physics for both
        // devices, per-device Eq. (1), the device-split budget epoch and
        // two device PIs — the unit of work a hetero node repeats every
        // simulated second. After warmup (trace logs pre-reserved, sinks
        // and aggregator scratch at their high-water marks) the loop must
        // allocate nothing.
        let cluster = Cluster::get(ClusterId::Gros);
        let cpu = DeviceSpec::cpu(&cluster);
        let gpu = DeviceSpec::gpu();
        let node = powerctl::sim::node::NodeSim::hetero(cluster.clone(), &[cpu.clone(), gpu.clone()], 42);
        let ctl = NodeBudgetController::new(
            DeviceSplitSpec::SlackShift.build(),
            vec![
                DeviceCtl::pi(&cpu, ideal_device_model(&cpu), 0.15, cpu.cap_max),
                DeviceCtl::pi(&gpu, ideal_device_model(&gpu), 0.15, gpu.cap_max),
            ],
        );
        let mut backend = HeteroBackend::new(node, ctl);
        // Bound total ticks so the pre-reserved logs cover warmup, the
        // timed section and the allocation-counted section.
        let iters: u64 = if smoke() { 500 } else { 20_000 };
        let counted: u64 = if smoke() { 200 } else { 2_000 };
        let rows = 200 + iters as usize + counted as usize + 64;
        backend.reserve_traces(rows);
        let mut engine = ControlLoop::new(backend, 1.0);
        engine.reserve_samples(rows);
        let budget = 0.7 * (cpu.cap_max + gpu.cap_max);
        engine.set_initial_pcap(budget);
        let mut policy = powerctl::control::baseline::StaticCap { pcap: budget };
        let mut now = 0.0;
        // Warmup to high-water marks (sinks, aggregator scratch, beat buf).
        for _ in 0..200 {
            now += 1.0;
            engine.tick(now, &mut policy);
        }
        let capped = Bench {
            warmup: std::time::Duration::ZERO,
            max_iterations: iters,
            ..Bench::scaled()
        };
        let r = capped.run("hetero_node_tick_cpu_gpu_split_plus_pis", || {
            now += 1.0;
            black_box(engine.tick(now, &mut policy));
        });
        report.add(&r);
        // Allocation check around a plain loop — Bench::run itself
        // allocates (sample log, sort, report strings), so the counter
        // must bracket only engine ticks (same pattern as the fleet
        // steady-state section above).
        let before = allocations();
        for _ in 0..counted {
            now += 1.0;
            engine.tick(now, &mut policy);
        }
        let delta = allocations() - before;
        println!("  allocations over {counted} steady-state hetero periods: {delta}");
        report.add_metric("hetero_steady_state_allocations", delta as f64);
        assert_eq!(
            delta, 0,
            "steady-state hierarchical tick path allocated {delta} times"
        );
    }

    let path = std::env::var("BENCH_L3_JSON").unwrap_or_else(|_| "BENCH_l3.json".to_string());
    report.save(&path).expect("write bench report");
    println!("\nbench report: {path} ({} entries)", report.len());
}
