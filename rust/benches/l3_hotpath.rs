//! `cargo bench --bench l3_hotpath` — L3 hot-path micro-benchmarks
//! (§Perf targets, DESIGN.md §7):
//!
//! * controller step: target ≪ 1 ms (sampling period is 1 s);
//! * Eq. (1) heartbeat ingestion + median: target ≥ 1 M beats/s;
//! * simulated node step: dominates campaign wall-time;
//! * one full closed-loop run (the fig7 unit of work);
//! * one fleet control period (16 engines + budget allocation), the new
//!   fleet hot path.

use powerctl::control::baseline::{PiPolicy, Uncontrolled};
use powerctl::control::budget::{BudgetPolicy, NodeReport, SlackProportional};
use powerctl::control::pi::{PiConfig, PiController};
use powerctl::coordinator::engine::{ControlLoop, LockstepBackend};
use powerctl::coordinator::experiment::{run_closed_loop, RunConfig};
use powerctl::coordinator::progress::ProgressAggregator;
use powerctl::experiments::{identify, Ctx, Scale};
use powerctl::fleet::{BudgetedPolicy, NodePolicySpec, NodeSpec};
use powerctl::sim::cluster::{Cluster, ClusterId};
use powerctl::sim::node::NodeSim;
use powerctl::util::bench::{black_box, section, Bench};

fn main() {
    let ctx = Ctx::new(std::env::temp_dir().join("powerctl-bench-l3"), 42, Scale::Fast);
    std::fs::create_dir_all(&ctx.out_dir).ok();
    let ident = identify(&ctx, ClusterId::Gros);
    let cluster = Cluster::get(ClusterId::Gros);
    let fast = Bench::default();

    section("controller");
    {
        let cfg = PiConfig::from_model(&ident.model, 10.0, 40.0, 120.0);
        let mut ctl = PiController::new(ident.model.clone(), cfg, 0.15);
        let mut t = 0.0;
        let r = fast.run("pi_controller_step", || {
            t += 1.0;
            black_box(ctl.step(t, 21.0 + (t % 3.0)));
        });
        assert!(
            r.mean < std::time::Duration::from_millis(1),
            "PI step must be ≪ 1 ms"
        );
    }

    section("progress aggregation (Eq. 1)");
    {
        // 1000 beats per window at ~25 Hz equivalent spacing.
        let mut agg = ProgressAggregator::new();
        let mut beats = Vec::with_capacity(1000);
        let mut base = 0.0;
        let r = fast.run("ingest_1000_beats_plus_median", || {
            beats.clear();
            for i in 0..1000 {
                beats.push(base + i as f64 * 0.04);
            }
            base += 40.0;
            agg.ingest(&beats);
            black_box(agg.sample());
        });
        let beats_per_sec = 1000.0 * r.ops_per_sec();
        println!("  → {:.2}M beats/s ingested+aggregated", beats_per_sec / 1e6);
        assert!(beats_per_sec > 1e6, "Eq. 1 path below 1M beats/s");
    }

    section("simulated node");
    {
        let mut node = NodeSim::new(cluster.clone(), 7);
        node.set_pcap(100.0);
        fast.run("node_step_1s_(20_substeps)", || {
            black_box(node.step(1.0));
        });
    }

    section("end-to-end closed-loop runs");
    {
        let slow = Bench::endtoend();
        let cfg = RunConfig {
            sample_period: 1.0,
            total_beats: 1_500,
            max_time: 600.0,
        };
        let mut seed = 0u64;
        slow.run("uncontrolled_run_1500_beats", || {
            seed += 1;
            let mut p = Uncontrolled { pcap_max: 120.0 };
            black_box(run_closed_loop(&cluster, &mut p, f64::NAN, 0.0, &cfg, seed));
        });
        slow.run("pi_run_1500_beats_eps0.15", || {
            seed += 1;
            let pic = PiConfig::from_model(&ident.model, 10.0, 40.0, 120.0);
            let ctl = PiController::new(ident.model.clone(), pic, 0.15);
            let sp = ctl.setpoint();
            let mut p = PiPolicy(ctl);
            black_box(run_closed_loop(&cluster, &mut p, sp, 0.15, &cfg, seed));
        });
    }

    section("fleet control period (16 nodes, in-process)");
    {
        // One fleet period = 16 engine ticks (node step + Eq. 1 + PI) plus
        // one budget allocation — the unit of work the fleet coordinator
        // repeats every simulated second. Engines run in-process here so
        // the number excludes thread handoff.
        const NODES: usize = 16;
        let spec = NodeSpec {
            cluster: ClusterId::Gros,
            model: ident.model.clone(),
            policy: NodePolicySpec::Pi { epsilon: 0.15 },
        };
        let share = 95.0;
        let mut engines: Vec<(ControlLoop<LockstepBackend>, BudgetedPolicy)> = (0..NODES)
            .map(|i| {
                let policy = BudgetedPolicy::new(&spec, &cluster, share);
                let node = NodeSim::new(cluster.clone(), 1000 + i as u64);
                let mut engine = ControlLoop::new(LockstepBackend::new(node), 1.0);
                engine.set_initial_pcap(policy.initial_pcap());
                (engine, policy)
            })
            .collect();
        let mut strategy = SlackProportional::default();
        let mut now = 0.0;
        // Cap iterations: every period appends one record row per engine.
        let capped = Bench {
            max_iterations: 20_000,
            ..Bench::default()
        };
        capped.run("fleet_period_16_nodes_tick_plus_alloc", || {
            now += 1.0;
            let mut reports = Vec::with_capacity(NODES);
            for (i, (engine, policy)) in engines.iter_mut().enumerate() {
                let s = engine.tick(now, policy);
                reports.push(NodeReport {
                    node_id: i as u32,
                    limit: policy.limit(),
                    pcap: s.pcap,
                    power: s.power,
                    progress: s.progress,
                    setpoint: policy.setpoint(),
                    pcap_min: cluster.pcap_min,
                    pcap_max: cluster.pcap_max,
                    done: false,
                });
            }
            let limits = strategy.allocate(now, share * NODES as f64, &reports);
            for ((_, policy), &l) in engines.iter_mut().zip(&limits) {
                policy.set_limit(l);
            }
            black_box(&limits);
        });
    }
}
