//! `cargo bench --bench runtime_pjrt` — L2/L1 runtime benchmarks: PJRT
//! compile time, per-iteration latency of the AOT STREAM step, and the
//! effective memory bandwidth implied by the kernel's 10N·4 bytes/step
//! (the STREAM metric itself).
//!
//! Skips (with a message) if `artifacts/` has not been built.

#[cfg(feature = "pjrt")]
use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::time::Instant;

#[cfg(feature = "pjrt")]
use powerctl::runtime::{Runtime, StreamExecutor};
#[cfg(feature = "pjrt")]
use powerctl::util::bench::{black_box, section, Bench};

#[cfg(feature = "pjrt")]
fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Without the `pjrt` feature the stub runtime cannot execute artifacts —
/// skip instead of panicking on the stub's constructor error.
#[cfg(not(feature = "pjrt"))]
fn main() {
    println!("runtime_pjrt: built without the `pjrt` feature; skipping");
}

#[cfg(feature = "pjrt")]
fn main() {
    if !artifacts_dir().join("manifest.json").exists() {
        println!("runtime_pjrt: artifacts/ not built (run `make artifacts`); skipping");
        return;
    }

    section("artifact load + compile");
    let t0 = Instant::now();
    let mut rt = Runtime::new(artifacts_dir()).expect("runtime");
    rt.load("stream_step").expect("compile stream_step");
    rt.load("stream_init").expect("compile stream_init");
    println!(
        "cold load+compile of both artifacts: {:.2} s (platform: {})",
        t0.elapsed().as_secs_f64(),
        rt.platform()
    );

    section("stream_step execution (per-variant; §Perf iteration log)");
    let bytes = rt.manifest.bytes_per_step as f64;
    let variants: Vec<&str> = {
        let mut v = vec!["stream_step", "stream_step_k"];
        let names: Vec<String> = rt.manifest.entries.keys().cloned().collect();
        drop(rt);
        for n in &names {
            if n.starts_with("stream_step_b") {
                v.push(Box::leak(n.clone().into_boxed_str()));
            }
        }
        v
    };
    let bench = Bench {
        warmup: std::time::Duration::from_millis(500),
        measure: std::time::Duration::from_secs(3),
        max_iterations: 200,
    };
    for entry in variants {
        let rt = Runtime::new(artifacts_dir()).expect("runtime");
        let Ok(mut ex) = StreamExecutor::with_entry(rt, entry, 1, false) else {
            println!("{entry:<28} (not in manifest; skipped)");
            continue;
        };
        let iters = ex.iters_per_call() as f64;
        let r = bench.run(&format!("{entry}_pjrt_call"), || {
            black_box(ex.step().expect("step"));
        });
        let per_iter = r.mean.as_secs_f64() / iters;
        let gbps = bytes / per_iter / 1e9;
        println!(
            "  → {iters:.0} iter/call ⇒ {:.2} ms/iter, effective STREAM bandwidth {gbps:.2} GB/s",
            per_iter * 1e3
        );
    }

    section("digest-checked execution (hot-path validation cost)");
    let rt2 = Runtime::new(artifacts_dir()).expect("runtime");
    let mut ex2 = StreamExecutor::new(rt2, 1, true).expect("executor");
    bench.run("stream_step_with_digest_check", || {
        black_box(ex2.step().expect("step"));
    });
}
