"""L2 model tests: stream_step vs oracle, init semantics, lowering sanity."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


@pytest.fixture(scope="module")
def small_state():
    # Use the artifact's real N only in test_lowering; elsewhere exercise the
    # same code path on a small N by calling kernels directly.
    key = jax.random.PRNGKey(5)
    ka, kb, kc = jax.random.split(key, 3)
    n = 4096
    a = jax.random.normal(ka, (n,), jnp.float32)
    b = jax.random.normal(kb, (n,), jnp.float32)
    c = jax.random.normal(kc, (n,), jnp.float32)
    return a, b, c


def test_init_matches_stream_semantics():
    (a,) = model.stream_init(jnp.int32(0))
    assert a.shape == (model.N,)
    np.testing.assert_allclose(np.asarray(a), 1.0, atol=1e-3)


def test_init_seed_jitter_distinct():
    (a0,) = model.stream_init(jnp.int32(1))
    (a1,) = model.stream_init(jnp.int32(2))
    assert not np.array_equal(np.asarray(a0), np.asarray(a1))


def test_step_matches_ref_on_artifact_size():
    (a,) = model.stream_init(jnp.int32(3))
    ga, gd = model.stream_step(a)
    wa, wd = model.stream_step_ref(a)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(wa), rtol=1e-5)
    np.testing.assert_allclose(float(gd), float(wd), rtol=1e-4)


def test_checksum_sensitive_to_state():
    (a,) = model.stream_init(jnp.int32(4))
    _, d0 = model.stream_step(a)
    _, d1 = model.stream_step(a + 1e-2)
    assert float(d0) != float(d1)
