"""AOT lowering tests: HLO text is produced, parseable-looking, and the
manifest agrees with the model constants."""

import json

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def step_hlo():
    return aot.lower_stream_step()


@pytest.fixture(scope="module")
def init_hlo():
    return aot.lower_stream_init()


def test_step_hlo_nonempty(step_hlo):
    assert "HloModule" in step_hlo
    assert "ENTRY" in step_hlo


def test_step_hlo_has_expected_shapes(step_hlo):
    # One f32[N] param and a tuple root with f32[N] plus a scalar digest.
    assert step_hlo.count(f"f32[{model.N}]") >= 2


def test_init_hlo_nonempty(init_hlo):
    assert "HloModule" in init_hlo
    assert "ENTRY" in init_hlo


def test_hlo_has_no_custom_call(step_hlo, init_hlo):
    # interpret=True must lower pallas to plain HLO ops — a Mosaic
    # custom-call would be unloadable by the CPU PJRT client.
    assert "custom-call" not in step_hlo
    assert "custom-call" not in init_hlo


def test_manifest_consistent():
    m = aot.manifest()
    assert m["n"] == model.N
    assert m["bytes_per_step"] == 10 * model.N * 4
    required = {"stream_step", "stream_step_k", "stream_init"}
    assert required <= set(m["entries"])
    # Perf variants carry their block size in the name.
    for blk in aot.PERF_BLOCKS:
        assert f"stream_step_b{blk}" in m["entries"]
    assert m["entries"]["stream_step_k"]["iters"] == aot.K_FUSED + 1
    json.dumps(m)  # serializable


def test_fused_step_matches_iterated_ref():
    import numpy as np
    import jax.numpy as jnp
    from compile.kernels import ref

    (a,) = model.stream_init(jnp.int32(2))
    got_a, got_d = model.stream_step_k(a, k=3)
    # Oracle: 4 plain iterations (k loop runs 3, plus the final one).
    ra = a
    for _ in range(4):
        ra, rd = model.stream_step_ref(ra)
    np.testing.assert_allclose(np.asarray(got_a), np.asarray(ra), rtol=1e-4)
    np.testing.assert_allclose(float(got_d), float(rd), rtol=1e-3)
