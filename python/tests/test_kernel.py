"""Pallas STREAM kernels vs pure-jnp oracle — the core L1 correctness signal.

hypothesis is not installed in this image, so shape/dtype/value coverage is
done with seeded parameter sweeps (deterministic, still dozens of distinct
cases per kernel).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.kernels import ref, stream

# (n, block) pairs covering: single block, many blocks, non-power-of-two
# multiples, tiny blocks, VPU-lane-sized blocks.
SHAPE_CASES = [
    (1024, 1024),
    (2048, 1024),
    (4096, 512),
    (8192, 2048),
    (3 * 1024, 1024),
    (5 * 256, 256),
    (1 << 14, 1 << 12),
    (1 << 16, 1 << 14),
]

DTYPES = [jnp.float32, jnp.float64]

SEEDS = [0, 1, 7, 42]


def _rand(key, n, dtype):
    x = jax.random.normal(key, (n,), jnp.float32) * 10.0
    return x.astype(dtype)


def _keys(seed, k):
    return jax.random.split(jax.random.PRNGKey(seed), k)


@pytest.mark.parametrize("n,block", SHAPE_CASES)
@pytest.mark.parametrize("seed", SEEDS[:2])
def test_copy_matches_ref(n, block, seed):
    (ka,) = _keys(seed, 1)
    a = _rand(ka, n, jnp.float32)
    got = stream.copy(a, block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.copy(a)), rtol=0)


@pytest.mark.parametrize("n,block", SHAPE_CASES)
@pytest.mark.parametrize("seed", SEEDS[:2])
def test_scale_matches_ref(n, block, seed):
    kc, ks = _keys(seed, 2)
    c = _rand(kc, n, jnp.float32)
    s = jax.random.uniform(ks, (), jnp.float32, 0.1, 5.0)
    got = stream.scale(c, s, block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.scale(c, s)), rtol=1e-6)


@pytest.mark.parametrize("n,block", SHAPE_CASES)
@pytest.mark.parametrize("seed", SEEDS[:2])
def test_add_matches_ref(n, block, seed):
    ka, kb = _keys(seed, 2)
    a = _rand(ka, n, jnp.float32)
    b = _rand(kb, n, jnp.float32)
    got = stream.add(a, b, block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.add(a, b)), rtol=1e-6)


@pytest.mark.parametrize("n,block", SHAPE_CASES)
@pytest.mark.parametrize("seed", SEEDS)
def test_triad_matches_ref(n, block, seed):
    kb, kc, ks = _keys(seed, 3)
    b = _rand(kb, n, jnp.float32)
    c = _rand(kc, n, jnp.float32)
    s = jax.random.uniform(ks, (), jnp.float32, 0.1, 5.0)
    got = stream.triad(b, c, s, block=block)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.triad(b, c, s)), rtol=1e-5, atol=1e-4
    )


@pytest.mark.parametrize("dtype", DTYPES)
def test_kernels_respect_dtype(dtype):
    if dtype == jnp.float64 and not jax.config.read("jax_enable_x64"):
        pytest.skip("x64 disabled")
    ka, kb, ks = _keys(3, 3)
    a = _rand(ka, 2048, dtype)
    b = _rand(kb, 2048, dtype)
    s = jnp.asarray(1.5, dtype)
    for out in stream.stream_iteration(a, b, jnp.zeros_like(a), s, block=1024):
        assert out.dtype == dtype


@pytest.mark.parametrize("seed", SEEDS)
def test_full_iteration_matches_ref(seed):
    ka, kb, kc, ks = _keys(seed, 4)
    n, block = 8192, 2048
    a = _rand(ka, n, jnp.float32)
    b = _rand(kb, n, jnp.float32)
    c = _rand(kc, n, jnp.float32)
    s = jax.random.uniform(ks, (), jnp.float32, 0.5, 4.0)
    got = stream.stream_iteration(a, b, c, s, block=block)
    want = ref.stream_iteration(a, b, c, s)
    for g, w, name in zip(got, want, "abc"):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-5, err_msg=f"array {name}"
        )


def test_iteration_is_pure():
    """Repeated application from identical state is deterministic."""
    ka, kb = _keys(11, 2)
    a = _rand(ka, 4096, jnp.float32)
    b = _rand(kb, 4096, jnp.float32)
    c = jnp.zeros_like(a)
    s = jnp.float32(3.0)
    r1 = stream.stream_iteration(a, b, c, s, block=1024)
    r2 = stream.stream_iteration(a, b, c, s, block=1024)
    for x, y in zip(r1, r2):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_block_mismatch_raises():
    a = jnp.zeros((1000,), jnp.float32)
    with pytest.raises(ValueError, match="multiple"):
        stream.copy(a, block=512)


def test_multi_iteration_stability():
    """STREAM iterated many times stays finite (values grow geometrically
    with s; use s<1 to keep bounded) and tracks the oracle."""
    n, block = 2048, 1024
    a = jnp.full((n,), 1.0, jnp.float32)
    b = jnp.full((n,), 2.0, jnp.float32)
    c = jnp.zeros((n,), jnp.float32)
    s = jnp.float32(0.5)
    ra, rb, rc = a, b, c
    for _ in range(10):
        a, b, c = stream.stream_iteration(a, b, c, s, block=block)
        ra, rb, rc = ref.stream_iteration(ra, rb, rc, s)
    for g, w in zip((a, b, c), (ra, rb, rc)):
        assert np.all(np.isfinite(np.asarray(g)))
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5)
