"""AOT lowering: JAX (L2) -> HLO *text* artifacts for the Rust runtime.

HLO text — NOT ``lowered.compile()`` / serialized HloModuleProto — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids that
the image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the XLA
text parser reassigns ids and round-trips cleanly. Pattern follows
/opt/xla-example/gen_hlo.py.

Usage (from the repo root, via ``make artifacts``)::

    cd python && python -m compile.aot --out ../artifacts

Emits:
    artifacts/stream_step.hlo.txt   (a,b,c) -> (a,b,c,digest)
    artifacts/stream_init.hlo.txt   seed    -> (a,b,c)
    artifacts/manifest.json         shapes + metadata for the Rust loader
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stream_step() -> str:
    spec = jax.ShapeDtypeStruct((model.N,), jnp.float32)
    return to_hlo_text(jax.jit(model.stream_step).lower(spec))


def lower_stream_step_k(k: int) -> str:
    spec = jax.ShapeDtypeStruct((model.N,), jnp.float32)
    return to_hlo_text(jax.jit(functools.partial(model.stream_step_k, k=k)).lower(spec))


def lower_stream_step_block(block: int) -> str:
    spec = jax.ShapeDtypeStruct((model.N,), jnp.float32)
    return to_hlo_text(
        jax.jit(functools.partial(model.stream_step_block, block=block)).lower(spec)
    )


def lower_stream_init() -> str:
    seed = jax.ShapeDtypeStruct((), jnp.int32)
    return to_hlo_text(jax.jit(model.stream_init).lower(seed))


# Fused-iteration factor of the stream_step_k artifact (§Perf).
K_FUSED = 8
# Tile-sweep variants (Pallas block sizes) for the §Perf analysis. 2**20 =
# whole-array tile (grid=1): fastest on the CPU interpret path but its
# 4 × 4 MiB working set exceeds a comfortable TPU VMEM budget — kept as a
# measurement point, not a default.
PERF_BLOCKS = (1 << 14, 1 << 16, 1 << 20)


def manifest() -> dict:
    entries = {
        "stream_step": {
            "file": "stream_step.hlo.txt",
            "iters": 1,
            "inputs": [["f32", [model.N]]],
            "outputs": [["f32", [model.N]], ["f32", []]],
        },
        "stream_step_k": {
            "file": "stream_step_k.hlo.txt",
            "iters": K_FUSED + 1,
            "inputs": [["f32", [model.N]]],
            "outputs": [["f32", [model.N]], ["f32", []]],
        },
        "stream_init": {
            "file": "stream_init.hlo.txt",
            "iters": 0,
            "inputs": [["s32", []]],
            "outputs": [["f32", [model.N]]],
        },
    }
    for blk in PERF_BLOCKS:
        entries[f"stream_step_b{blk}"] = {
            "file": f"stream_step_b{blk}.hlo.txt",
            "iters": 1,
            "inputs": [["f32", [model.N]]],
            "outputs": [["f32", [model.N]], ["f32", []]],
        }
    return {
        "n": model.N,
        "block": model.BLOCK,
        "scalar": model.SCALAR,
        "dtype": "f32",
        "k_fused": K_FUSED + 1,
        "entries": entries,
        # Bytes moved per stream_step on an ideal bandwidth-bound machine:
        # copy 2N + scale 2N + add 3N + triad 3N = 10N floats.
        "bytes_per_step": 10 * model.N * 4,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    jobs = [
        ("stream_step", lower_stream_step),
        ("stream_step_k", lambda: lower_stream_step_k(K_FUSED)),
        ("stream_init", lower_stream_init),
    ]
    for blk in PERF_BLOCKS:
        jobs.append((f"stream_step_b{blk}", functools.partial(lower_stream_step_block, blk)))
    for name, fn in jobs:
        path = os.path.join(args.out, f"{name}.hlo.txt")
        text = fn()
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest(), f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
