"""L2 — JAX compute graph for the instrumented STREAM benchmark.

The "model" of this paper is not a neural network: the compute artifact the
Rust coordinator executes per heartbeat is one (or a fused batch of) STREAM
loop iterations, built from the L1 Pallas kernels. Two entry points are
AOT-lowered (see aot.py):

  * ``stream_step``   — one loop iteration (4 kernels) + checksum. This is
    the unit of work whose completion emits one heartbeat.
  * ``stream_init``   — deterministic array initialization (STREAM 5.10's
    a=1, b=2, c=0 scaled by a seed-derived jitter so repeated runs differ),
    so the Rust side never materializes host-side arrays beyond feeding a
    seed scalar.

Both are lowered with all array state as explicit inputs/outputs so the Rust
runtime can keep buffers device-resident across iterations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref, stream

# Problem size of the AOT artifact. See kernels/stream.py for why this is
# smaller than STREAM 5.10's 2**25 (interpret=True wall-clock; the plant,
# not wall-clock, paces experiment time).
N = stream.DEFAULT_N
BLOCK = stream.DEFAULT_BLOCK
# STREAM's scalar is arbitrary for bandwidth purposes (McCalpin uses 3.0).
# We pick s = √2 − 1, the positive root of s² + 2s − 1 = 0, which makes the
# loop-carried update a' = (2s + s²)·a = a exactly norm-preserving: the
# artifact can iterate indefinitely without f32 overflow (STREAM 5.10 only
# runs NTIMES=10, so its growth never bites; our runs do 10⁴ iterations).
SCALAR = 0.4142135623730951


def stream_init(seed: jax.Array):
    """Initial `a` array; a tiny seed-derived jitter keeps distinct runs
    numerically distinct while matching STREAM's a=1 init."""
    jitter = (seed.astype(jnp.float32) % 977.0) * 1e-6
    return (jnp.full((N,), 1.0, jnp.float32) + jitter,)


def stream_step(a: jax.Array):
    """One heartbeat's worth of work: copy, scale, add, triad + checksum.

    STREAM's loop only carries `a` across iterations (c = copy(a),
    b = s·c, c = a+b, a = b+s·c): b and c are recomputed every pass, so the
    AOT artifact takes a single array input and returns the next `a` plus a
    checksum digest. XLA would prune unused b/c params anyway.
    """
    s = jnp.float32(SCALAR)
    b = jnp.zeros_like(a)
    c = jnp.zeros_like(a)
    a, b, c = stream.stream_iteration(a, b, c, s, block=BLOCK)
    digest = ref.stream_checksum(a, b, c)
    return a, digest


def stream_step_k(a: jax.Array, k: int, block: int = BLOCK):
    """`k` fused STREAM iterations in one artifact call (§Perf).

    Each PJRT call costs a host→device upload of `a` and a device→host
    download of the result (~2·4·N bytes of PCIe-equivalent traffic on real
    hardware, plus dispatch latency). Folding k iterations into one
    executable with `lax.fori_loop` amortizes that overhead k× while
    keeping per-iteration STREAM semantics; the caller credits k heartbeats
    per call (the transport's `units` field exists for exactly this).
    """
    s = jnp.float32(SCALAR)

    def body(_, carry):
        a = carry
        b = jnp.zeros_like(a)
        c = jnp.zeros_like(a)
        a, _, _ = stream.stream_iteration(a, b, c, s, block=block)
        return a

    a = jax.lax.fori_loop(0, k, body, a)
    b = jnp.zeros_like(a)
    c = jnp.zeros_like(a)
    a, b, c = stream.stream_iteration(a, b, c, s, block=block)
    digest = ref.stream_checksum(a, b, c)
    return a, digest


def stream_step_block(a: jax.Array, block: int):
    """stream_step lowered at an alternative Pallas block size (tile-sweep
    variants for the §Perf analysis)."""
    s = jnp.float32(SCALAR)
    b = jnp.zeros_like(a)
    c = jnp.zeros_like(a)
    a, b, c = stream.stream_iteration(a, b, c, s, block=block)
    digest = ref.stream_checksum(a, b, c)
    return a, digest


def stream_step_ref(a: jax.Array):
    """Oracle twin of stream_step (pure jnp) for pytest comparison."""
    s = jnp.float32(SCALAR)
    b = jnp.zeros_like(a)
    c = jnp.zeros_like(a)
    a, b, c = ref.stream_iteration(a, b, c, s)
    digest = ref.stream_checksum(a, b, c)
    return a, digest
