"""Pure-jnp oracle for the Pallas STREAM kernels (correctness reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def copy(a: jax.Array) -> jax.Array:
    return jnp.asarray(a)


def scale(c: jax.Array, s: jax.Array) -> jax.Array:
    return s.astype(c.dtype) * c


def add(a: jax.Array, b: jax.Array) -> jax.Array:
    return a + b


def triad(b: jax.Array, c: jax.Array, s: jax.Array) -> jax.Array:
    return b + s.astype(b.dtype) * c


def stream_iteration(a, b, c, s):
    c = copy(a)
    b = scale(c, s)
    c = add(a, b)
    a = triad(b, c, s)
    return a, b, c


def stream_checksum(a, b, c):
    """Scalar digest used by the rust runtime to validate artifact numerics."""
    return jnp.sum(a) + 2.0 * jnp.sum(b) + 3.0 * jnp.sum(c)
