"""L1 — Pallas STREAM kernels.

The paper's workload is McCalpin's STREAM 5.10 (memory-bound): four kernels
run in a loop, one heartbeat per loop completion.

    copy :  c[i] = a[i]
    scale:  b[i] = s * c[i]
    add  :  c[i] = a[i] + b[i]
    triad:  a[i] = b[i] + s * c[i]

Hardware adaptation (see DESIGN.md §3): the paper runs STREAM on Xeon
packages where the power knee comes from DRAM bandwidth saturation. The TPU
analogue is an HBM-bandwidth-bound kernel that keeps the MXU idle: we tile
each 1-D array over a grid with `BlockSpec`, stream HBM->VMEM block by
block, and do element-wise VPU work only. `interpret=True` everywhere —
CPU PJRT cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).

Block size: STREAM arrays are contiguous f32 vectors. A (8, 128)-multiple
flat tile keeps the VPU lanes full; `BLOCK` elements of each operand live in
VMEM at once. With the default BLOCK=65536 a triad tile holds
3 * 65536 * 4 B = 768 KiB in VMEM — comfortably under the ~16 MiB budget and
large enough that the HBM stream dominates (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default element count per kernel invocation. STREAM 5.10 in the paper uses
# N = 33_554_432 (2**25) per array; under interpret=True that wall-clock is
# prohibitive, and experiment pacing comes from the simulated plant (see
# DESIGN.md §2), so artifacts are built at a smaller N that preserves the
# bandwidth-bound structure.
DEFAULT_N = 1 << 20
# Elements per grid step. Multiple of 8*128 = 1024 VPU lanes.
#
# §Perf: raised from 2**16 to 2**18 after the tile sweep (see
# EXPERIMENTS.md §Perf): on the CPU interpret path the per-grid-step
# overhead dominates, and 2**18 (grid=4) ran the STREAM step 2.5× faster
# than 2**16 (grid=16). On a real TPU the triad tile then holds
# 3 inputs + 1 output × 1 MiB = 4 MiB in VMEM — comfortably inside the
# ~16 MiB budget while still double-bufferable.
DEFAULT_BLOCK = 1 << 18


def _grid(n: int, block: int) -> int:
    if n % block != 0:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    return n // block


# --- kernel bodies (shared element-wise cores) -------------------------------


def _copy_kernel(a_ref, c_ref):
    c_ref[...] = a_ref[...]


def _scale_kernel(c_ref, s_ref, b_ref):
    # s is a (1, 1) scalar tile broadcast over the block.
    b_ref[...] = s_ref[0, 0] * c_ref[...]


def _add_kernel(a_ref, b_ref, c_ref):
    c_ref[...] = a_ref[...] + b_ref[...]


def _triad_kernel(b_ref, c_ref, s_ref, a_ref):
    a_ref[...] = b_ref[...] + s_ref[0, 0] * c_ref[...]


# --- pallas_call wrappers -----------------------------------------------------
#
# All arrays are shaped (n,) logically; we view them as (n/block, block) rows
# and grid over rows so each grid step streams one `block`-element tile
# through VMEM. The scalar `s` rides along as a (1, 1) block replicated to
# every grid step.


def _vec_spec(block: int):
    return pl.BlockSpec((1, block), lambda i: (i, 0))


def _scalar_spec():
    return pl.BlockSpec((1, 1), lambda i: (0, 0))


def _as_rows(x: jax.Array, block: int) -> jax.Array:
    return x.reshape((-1, block))


@functools.partial(jax.jit, static_argnames=("block",))
def copy(a: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """STREAM copy: returns c = a."""
    n = a.shape[0]
    g = _grid(n, block)
    out = pl.pallas_call(
        _copy_kernel,
        grid=(g,),
        in_specs=[_vec_spec(block)],
        out_specs=_vec_spec(block),
        out_shape=jax.ShapeDtypeStruct((g, block), a.dtype),
        interpret=True,
    )(_as_rows(a, block))
    return out.reshape((n,))


@functools.partial(jax.jit, static_argnames=("block",))
def scale(c: jax.Array, s: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """STREAM scale: returns b = s * c."""
    n = c.shape[0]
    g = _grid(n, block)
    out = pl.pallas_call(
        _scale_kernel,
        grid=(g,),
        in_specs=[_vec_spec(block), _scalar_spec()],
        out_specs=_vec_spec(block),
        out_shape=jax.ShapeDtypeStruct((g, block), c.dtype),
        interpret=True,
    )(_as_rows(c, block), s.reshape((1, 1)).astype(c.dtype))
    return out.reshape((n,))


@functools.partial(jax.jit, static_argnames=("block",))
def add(a: jax.Array, b: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """STREAM add: returns c = a + b."""
    n = a.shape[0]
    g = _grid(n, block)
    out = pl.pallas_call(
        _add_kernel,
        grid=(g,),
        in_specs=[_vec_spec(block), _vec_spec(block)],
        out_specs=_vec_spec(block),
        out_shape=jax.ShapeDtypeStruct((g, block), a.dtype),
        interpret=True,
    )(_as_rows(a, block), _as_rows(b, block))
    return out.reshape((n,))


@functools.partial(jax.jit, static_argnames=("block",))
def triad(b: jax.Array, c: jax.Array, s: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """STREAM triad: returns a = b + s * c."""
    n = b.shape[0]
    g = _grid(n, block)
    out = pl.pallas_call(
        _triad_kernel,
        grid=(g,),
        in_specs=[_vec_spec(block), _vec_spec(block), _scalar_spec()],
        out_specs=_vec_spec(block),
        out_shape=jax.ShapeDtypeStruct((g, block), b.dtype),
        interpret=True,
    )(_as_rows(b, block), _as_rows(c, block), s.reshape((1, 1)).astype(b.dtype))
    return out.reshape((n,))


def stream_iteration(
    a: jax.Array, b: jax.Array, c: jax.Array, s: jax.Array, *, block: int = DEFAULT_BLOCK
):
    """One STREAM loop body (paper §4.1): copy, scale, add, triad.

    Returns the updated (a, b, c) triple — exactly the data flow of
    STREAM 5.10's main loop, so iterating this function is the instrumented
    benchmark whose completion emits one heartbeat.
    """
    c = copy(a, block=block)
    b = scale(c, s, block=block)
    c = add(a, b, block=block)
    a = triad(b, c, s, block=block)
    return a, b, c
